//! The nine paper kernels (§8.1.2). Each builder returns IR (textual,
//! parsed) + seeded data + the paper-default parameters; C-level
//! pseudo-code of the original benchmark shape is kept in comments.
//! `rust_reference` re-implements every kernel directly in Rust as an
//! independent functional oracle.

use super::graph;
use super::{ints, set_ints, Workload};
use crate::ir::parser::parse_module;
use crate::ir::types::Val;
use crate::sim::{zero_memory, Memory};
use crate::util::Rng;

fn make(name: &str, src: &str, args: Vec<Val>, memory: Memory, knob: Option<f64>) -> Workload {
    let module = parse_module(src).unwrap_or_else(|e| panic!("{name} IR: {e}"));
    Workload { name: name.to_string(), module, args, memory, target_misspec: knob }
}

// ---------------------------------------------------------------------------
// hist — histogram with saturating bins (paper: "similar to Figure 1b",
// size 1000). C shape:
//     for (i = 0; i < n; ++i) { v = d[i]; if (H[v] < CAP) H[v] += 1; }
// Mis-speculation knob: a fraction `rate` of elements points at
// pre-saturated bins, so their store is skipped (poisoned under SPEC).
// ---------------------------------------------------------------------------

pub const HIST_N: usize = 1000;
pub const HIST_BINS: usize = 256;
pub const HIST_CAP: i64 = 1 << 20;

pub fn hist(seed: u64, rate: f64) -> Workload {
    let src = format!(
        r#"
array @d : i64[{n}]
array @H : i64[{b}]

func @hist(%n: i64) {{
entry:
  %c0 = const.i 0
  br header
header:
  %i = phi i64 [entry: %c0], [latch: %inext]
  %cc = icmp.lt %i, %n
  condbr %cc, body, exit
body:
  %v = load @d[%i]
  %h = load @H[%v]
  %cap = const.i {cap}
  %p = icmp.lt %h, %cap
  condbr %p, then, latch
then:
  %c1 = const.i 1
  %h1 = add.i %h, %c1
  store @H[%v], %h1
  br latch
latch:
  %c1b = const.i 1
  %inext = add.i %i, %c1b
  br header
exit:
  ret
}}
"#,
        n = HIST_N,
        b = HIST_BINS,
        cap = HIST_CAP
    );
    let module = parse_module(&src).unwrap();
    let mut memory = zero_memory(&module);
    let mut rng = Rng::new(seed);
    // half the bins are pre-saturated; elements pick one with prob `rate`
    let sat_bins = HIST_BINS / 2;
    let mut d = vec![0i64; HIST_N];
    for x in d.iter_mut() {
        *x = if rng.chance(rate) {
            rng.below(sat_bins as u64) as i64 // saturated half
        } else {
            sat_bins as i64 + rng.below((HIST_BINS - sat_bins) as u64) as i64
        };
    }
    set_ints(&mut memory, 0, &d);
    let h: Vec<i64> =
        (0..HIST_BINS).map(|b| if b < sat_bins { HIST_CAP } else { 0 }).collect();
    set_ints(&mut memory, 1, &h);
    make("hist", &src, vec![Val::I(HIST_N as i64)], memory, Some(rate))
}

// ---------------------------------------------------------------------------
// thr — zero RGB pixels above a luminance threshold (paper: size 1000).
//     for (i) { s = R[i]+G[i]+B[i]; if (s > T) { R[i]=G[i]=B[i]=0; } }
// 3 control-dependent stores guarded by loads of the stored arrays
// (paper Table 1: 1 poison block, 3 calls). Knob: fraction of pixels
// below the threshold (mis-speculated).
// ---------------------------------------------------------------------------

pub const THR_N: usize = 1000;
pub const THR_T: i64 = 300;

pub fn thr(seed: u64, rate: f64) -> Workload {
    let src = format!(
        r#"
array @R : i64[{n}]
array @G : i64[{n}]
array @B : i64[{n}]

func @thr(%n: i64) {{
entry:
  %c0 = const.i 0
  br header
header:
  %i = phi i64 [entry: %c0], [latch: %inext]
  %cc = icmp.lt %i, %n
  condbr %cc, body, exit
body:
  %r = load @R[%i]
  %g = load @G[%i]
  %b = load @B[%i]
  %s1 = add.i %r, %g
  %s = add.i %s1, %b
  %t = const.i {t}
  %p = icmp.gt %s, %t
  condbr %p, then, latch
then:
  %z = const.i 0
  store @R[%i], %z
  store @G[%i], %z
  store @B[%i], %z
  br latch
latch:
  %c1 = const.i 1
  %inext = add.i %i, %c1
  br header
exit:
  ret
}}
"#,
        n = THR_N,
        t = THR_T
    );
    let module = parse_module(&src).unwrap();
    let mut memory = zero_memory(&module);
    let mut rng = Rng::new(seed);
    let (mut r, mut g, mut b) = (vec![0i64; THR_N], vec![0i64; THR_N], vec![0i64; THR_N]);
    for i in 0..THR_N {
        if rng.chance(rate) {
            // below threshold: sum < 270
            r[i] = rng.range_i64(0, 90);
            g[i] = rng.range_i64(0, 90);
            b[i] = rng.range_i64(0, 90);
        } else {
            // above: each channel >= 101 → sum >= 303 > 300
            r[i] = rng.range_i64(101, 200);
            g[i] = rng.range_i64(101, 200);
            b[i] = rng.range_i64(101, 200);
        }
    }
    set_ints(&mut memory, 0, &r);
    set_ints(&mut memory, 1, &g);
    set_ints(&mut memory, 2, &b);
    make("thr", &src, vec![Val::I(THR_N as i64)], memory, Some(rate))
}

// ---------------------------------------------------------------------------
// mm — greedy maximal matching on a bipartite-ish edge list (paper:
// 2000 edges; Table 1: 1 poison block, 2 calls, 31% mis-spec).
//     for (e) { u=eu[e]; v=ev[e];
//               if (match[u]==-1 && match[v]==-1) { match[u]=v; match[v]=u; } }
// The && is evaluated arithmetically (mu+mv == -2) to keep both loads
// unconditional, as HLS if-conversion would.
// ---------------------------------------------------------------------------

pub const MM_E: usize = 2000;
pub const MM_V: usize = 4200;

pub fn mm(seed: u64, rate: f64) -> Workload {
    let src = format!(
        r#"
array @eu : i64[{e}]
array @ev : i64[{e}]
array @match : i64[{v}]

func @mm(%n: i64) {{
entry:
  %c0 = const.i 0
  br header
header:
  %i = phi i64 [entry: %c0], [latch: %inext]
  %cc = icmp.lt %i, %n
  condbr %cc, body, exit
body:
  %u = load @eu[%i]
  %v = load @ev[%i]
  %mu = load @match[%u]
  %mv = load @match[%v]
  %sum = add.i %mu, %mv
  %m2 = const.i -2
  %p = icmp.eq %sum, %m2
  condbr %p, then, latch
then:
  store @match[%u], %v
  store @match[%v], %u
  br latch
latch:
  %c1 = const.i 1
  %inext = add.i %i, %c1
  br header
exit:
  ret
}}
"#,
        e = MM_E,
        v = MM_V
    );
    let module = parse_module(&src).unwrap();
    let mut memory = zero_memory(&module);
    let mut rng = Rng::new(seed);
    // construct the edge list so that ~rate of edges hit already-matched
    // endpoints: simulate the greedy matching while generating.
    let mut matched: Vec<i64> = Vec::new(); // nodes matched so far
    let mut fresh_next: i64 = 0;
    let (mut eu, mut ev) = (vec![0i64; MM_E], vec![0i64; MM_E]);
    for i in 0..MM_E {
        if !matched.is_empty() && rng.chance(rate) {
            // conflict edge: at least one endpoint already matched
            let a = matched[rng.below(matched.len() as u64) as usize];
            let b = if rng.chance(0.5) && matched.len() > 1 {
                matched[rng.below(matched.len() as u64) as usize]
            } else {
                fresh_next + rng.range_i64(0, (MM_V as i64 - fresh_next).max(1))
            };
            eu[i] = a;
            ev[i] = if b == a { (a + 1) % MM_V as i64 } else { b };
        } else if fresh_next + 2 <= MM_V as i64 {
            eu[i] = fresh_next;
            ev[i] = fresh_next + 1;
            matched.push(fresh_next);
            matched.push(fresh_next + 1);
            fresh_next += 2;
        } else {
            let a = matched[rng.below(matched.len() as u64) as usize];
            eu[i] = a;
            ev[i] = (a + 1) % MM_V as i64;
        }
    }
    set_ints(&mut memory, 0, &eu);
    set_ints(&mut memory, 1, &ev);
    set_ints(&mut memory, 2, &vec![-1i64; MM_V]);
    make("mm", &src, vec![Val::I(MM_E as i64)], memory, Some(rate))
}

// ---------------------------------------------------------------------------
// fw — Floyd-Warshall all-pairs distances on a dense 10×10 matrix.
//     for k for i for j:
//       if (d[ik]+d[kj] < d[ij]) d[ij] = d[ik]+d[kj];
// ---------------------------------------------------------------------------

pub const FW_N: usize = 10;

pub fn fw(seed: u64) -> Workload {
    let src = format!(
        r#"
array @dist : i64[{nn}]

func @fw(%n: i64) {{
entry:
  %c0 = const.i 0
  br kh
kh:
  %k = phi i64 [entry: %c0], [klatch: %knext]
  %ck = icmp.lt %k, %n
  condbr %ck, ih, exit
ih:
  %i = phi i64 [kh: %c0], [ilatch: %inext]
  %ci = icmp.lt %i, %n
  condbr %ci, jh, klatch
jh:
  %j = phi i64 [ih: %c0], [jlatch: %jnext]
  %cj = icmp.lt %j, %n
  condbr %cj, body, ilatch
body:
  %in = mul.i %i, %n
  %ij = add.i %in, %j
  %ik = add.i %in, %k
  %kn = mul.i %k, %n
  %kj = add.i %kn, %j
  %dij = load @dist[%ij]
  %dik = load @dist[%ik]
  %dkj = load @dist[%kj]
  %s = add.i %dik, %dkj
  %p = icmp.lt %s, %dij
  condbr %p, then, jlatch
then:
  store @dist[%ij], %s
  br jlatch
jlatch:
  %c1 = const.i 1
  %jnext = add.i %j, %c1
  br jh
ilatch:
  %c1i = const.i 1
  %inext = add.i %i, %c1i
  br ih
klatch:
  %c1k = const.i 1
  %knext = add.i %k, %c1k
  br kh
exit:
  ret
}}
"#,
        nn = FW_N * FW_N
    );
    let module = parse_module(&src).unwrap();
    let mut memory = zero_memory(&module);
    let mut rng = Rng::new(seed);
    let mut d = vec![0i64; FW_N * FW_N];
    for i in 0..FW_N {
        for j in 0..FW_N {
            d[i * FW_N + j] = if i == j { 0 } else { rng.range_i64(1, 100) };
        }
    }
    set_ints(&mut memory, 0, &d);
    make("fw", &src, vec![Val::I(FW_N as i64)], memory, None)
}

// ---------------------------------------------------------------------------
// sort — bitonic merge sort, in place (paper: size 64; Table 1: 1 poison
// block, 2 calls, 49% mis-spec).
//     for (k=2; k<=n; k*=2) for (j=k/2; j>0; j/=2) for (i=0; i<n; ++i) {
//       l = i^j;
//       if (l > i) { up = (i&k)==0;
//         if (up ? a[i]>a[l] : a[i]<a[l]) swap(a[i], a[l]); } }
// ---------------------------------------------------------------------------

pub const SORT_N: usize = 64;

pub fn sort(seed: u64) -> Workload {
    let src = format!(
        r#"
array @a : i64[{n}]

func @sort(%n: i64) {{
entry:
  %c0 = const.i 0
  %c1 = const.i 1
  %c2 = const.i 2
  br kh
kh:
  %k = phi i64 [entry: %c2], [klatch: %knext]
  %ck = icmp.le %k, %n
  condbr %ck, kpre, exit
kpre:
  %jinit = div.i %k, %c2
  br jh
jh:
  %j = phi i64 [kpre: %jinit], [jlatch: %jnext]
  %cj = icmp.gt %j, %c0
  condbr %cj, ihh, klatch
ihh:
  %i = phi i64 [jh: %c0], [ilatch: %inext]
  %ci2 = icmp.lt %i, %n
  condbr %ci2, body, jlatch
body:
  %l = xor.i %i, %j
  %cl = icmp.gt %l, %i
  condbr %cl, cmpblk, ilatch
cmpblk:
  %x = load @a[%i]
  %y = load @a[%l]
  %ik = and.i %i, %k
  %up = icmp.eq %ik, %c0
  %gt = icmp.gt %x, %y
  %lt = icmp.lt %x, %y
  %want = select %up, %gt, %lt
  condbr %want, swap, ilatch
swap:
  store @a[%i], %y
  store @a[%l], %x
  br ilatch
ilatch:
  %inext = add.i %i, %c1
  br ihh
jlatch:
  %jnext = div.i %j, %c2
  br jh
klatch:
  %knext = mul.i %k, %c2
  br kh
exit:
  ret
}}
"#,
        n = SORT_N
    );
    let module = parse_module(&src).unwrap();
    let mut memory = zero_memory(&module);
    let mut rng = Rng::new(seed);
    let a: Vec<i64> = (0..SORT_N).map(|_| rng.range_i64(0, 1000)).collect();
    set_ints(&mut memory, 0, &a);
    make("sort", &src, vec![Val::I(SORT_N as i64)], memory, None)
}

// ---------------------------------------------------------------------------
// spmv — sparse matrix-vector multiply with saturating scatter
// accumulation (paper: 20×20; adapted to carry the paper's LoD shape —
// the accumulator array is both guard-loaded and stored, see DESIGN.md).
//     for (nz) { r=ri[nz]; c=ci[nz]; v=va[nz];
//                if (y[c] < CAP) y[c] += v * x[r]; }
// ---------------------------------------------------------------------------

pub const SPMV_N: usize = 20;
pub const SPMV_NNZ: usize = 400;
pub const SPMV_CAP: i64 = 1 << 30;

pub fn spmv(seed: u64, rate: f64) -> Workload {
    let src = format!(
        r#"
array @ri : i64[{nnz}]
array @ci : i64[{nnz}]
array @va : i64[{nnz}]
array @x : i64[{n}]
array @y : i64[{n}]

func @spmv(%nnz: i64) {{
entry:
  %c0 = const.i 0
  br header
header:
  %i = phi i64 [entry: %c0], [latch: %inext]
  %cc = icmp.lt %i, %nnz
  condbr %cc, body, exit
body:
  %r = load @ri[%i]
  %c = load @ci[%i]
  %v = load @va[%i]
  %xr = load @x[%r]
  %prod = mul.i %v, %xr
  %yc = load @y[%c]
  %cap = const.i {cap}
  %p = icmp.lt %yc, %cap
  condbr %p, then, latch
then:
  %ny = add.i %yc, %prod
  store @y[%c], %ny
  br latch
latch:
  %c1 = const.i 1
  %inext = add.i %i, %c1
  br header
exit:
  ret
}}
"#,
        nnz = SPMV_NNZ,
        n = SPMV_N,
        cap = SPMV_CAP
    );
    let module = parse_module(&src).unwrap();
    let mut memory = zero_memory(&module);
    let mut rng = Rng::new(seed);
    // saturated columns chosen to cover ~rate of the nnz entries
    let n_sat = ((SPMV_N as f64) * rate).round() as usize;
    let (mut ri, mut ci, mut va) =
        (vec![0i64; SPMV_NNZ], vec![0i64; SPMV_NNZ], vec![0i64; SPMV_NNZ]);
    for i in 0..SPMV_NNZ {
        ri[i] = (i / SPMV_N) as i64;
        ci[i] = (i % SPMV_N) as i64;
        va[i] = rng.range_i64(1, 10);
    }
    let x: Vec<i64> = (0..SPMV_N).map(|_| rng.range_i64(1, 10)).collect();
    let y: Vec<i64> =
        (0..SPMV_N).map(|c| if c < n_sat { SPMV_CAP } else { 0 }).collect();
    set_ints(&mut memory, 0, &ri);
    set_ints(&mut memory, 1, &ci);
    set_ints(&mut memory, 2, &va);
    set_ints(&mut memory, 3, &x);
    set_ints(&mut memory, 4, &y);
    make("spmv", &src, vec![Val::I(SPMV_NNZ as i64)], memory, Some(rate))
}

// ---------------------------------------------------------------------------
// bfs — level-synchronous breadth-first traversal over the synthetic
// email-Eu-core graph (paper replaced the dynamic frontier queue with an
// HLS library structure; the level-synchronous form is the standard
// queue-free HLS formulation — see DESIGN.md).
//     for (lvl = 0; lvl < L; ++lvl)
//       for (u = 0; u < V; ++u)
//         if (dist[u] == lvl)
//           for (e = rowp[u]; e < rowp[u+1]; ++e) {
//             v = col[e];
//             if (dist[v] == -1) dist[v] = lvl + 1;  // LoD store
//           }
// ---------------------------------------------------------------------------

pub const BFS_LEVELS: i64 = 10;

pub fn bfs(seed: u64) -> Workload {
    let g = graph::email_eu_core_like(seed);
    let src = format!(
        r#"
array @rowp : i64[{np1}]
array @col : i64[{m}]
array @dist : i64[{n}]

func @bfs(%nv: i64, %nlvl: i64) {{
entry:
  %c0 = const.i 0
  %c1 = const.i 1
  %cm1 = const.i -1
  br lh
lh:
  %lvl = phi i64 [entry: %c0], [llatch: %lnext]
  %cl = icmp.lt %lvl, %nlvl
  condbr %cl, uh, exit
uh:
  %u = phi i64 [lh: %c0], [ulatch: %unext]
  %cu = icmp.lt %u, %nv
  condbr %cu, ubody, llatch
ubody:
  %du = load @dist[%u]
  %on = icmp.eq %du, %lvl
  condbr %on, epre, ulatch
epre:
  %rb = load @rowp[%u]
  %u1 = add.i %u, %c1
  %re = load @rowp[%u1]
  %l1 = add.i %lvl, %c1
  br eh
eh:
  %e = phi i64 [epre: %rb], [el: %enext]
  %ce = icmp.lt %e, %re
  condbr %ce, ebody, ulatch2
ebody:
  %v = load @col[%e]
  %dv = load @dist[%v]
  %fresh = icmp.eq %dv, %cm1
  condbr %fresh, mark, el
mark:
  store @dist[%v], %l1
  br el
el:
  %enext = add.i %e, %c1
  br eh
ulatch2:
  br ulatch
ulatch:
  %unext = add.i %u, %c1
  br uh
llatch:
  %lnext = add.i %lvl, %c1
  br lh
exit:
  ret
}}
"#,
        np1 = g.n + 1,
        m = g.m,
        n = g.n
    );
    let module = parse_module(&src).unwrap();
    let mut memory = zero_memory(&module);
    set_ints(&mut memory, 0, &g.rowp);
    set_ints(&mut memory, 1, &g.col);
    let mut dist = vec![-1i64; g.n];
    dist[0] = 0; // source = node 0
    set_ints(&mut memory, 2, &dist);
    make(
        "bfs",
        &src,
        vec![Val::I(g.n as i64), Val::I(BFS_LEVELS)],
        memory,
        None,
    )
}

// ---------------------------------------------------------------------------
// sssp — single-source shortest paths via bounded Bellman-Ford
// relaxation sweeps over the edge list (the paper's Dijkstra priority
// queue is a dynamic structure it too replaced; relaxation sweeps keep
// the identical LoD store shape — see DESIGN.md).
//     for (r = 0; r < R; ++r)
//       for (e) { if (dist[eu[e]] + w[e] < dist[ev[e]]) dist[ev[e]] = ...; }
// ---------------------------------------------------------------------------

pub const SSSP_ROUNDS: i64 = 2;
pub const SSSP_INF: i64 = 1 << 40;

pub fn sssp(seed: u64) -> Workload {
    let g = graph::email_eu_core_like(seed);
    let (eu, ev, ew) = graph::edge_list(&g, seed, 9);
    let src = format!(
        r#"
array @eu : i64[{m}]
array @ev : i64[{m}]
array @ew : i64[{m}]
array @dist : i64[{n}]

func @sssp(%m: i64, %rounds: i64) {{
entry:
  %c0 = const.i 0
  %c1 = const.i 1
  br rh
rh:
  %r = phi i64 [entry: %c0], [rlatch: %rnext]
  %cr = icmp.lt %r, %rounds
  condbr %cr, eh, exit
eh:
  %e = phi i64 [rh: %c0], [el: %enext]
  %ce = icmp.lt %e, %m
  condbr %ce, body, rlatch
body:
  %u = load @eu[%e]
  %v = load @ev[%e]
  %w = load @ew[%e]
  %du = load @dist[%u]
  %dv = load @dist[%v]
  %nd = add.i %du, %w
  %p = icmp.lt %nd, %dv
  condbr %p, relax, el
relax:
  store @dist[%v], %nd
  br el
el:
  %enext = add.i %e, %c1
  br eh
rlatch:
  %rnext = add.i %r, %c1
  br rh
exit:
  ret
}}
"#,
        m = g.m,
        n = g.n
    );
    let module = parse_module(&src).unwrap();
    let mut memory = zero_memory(&module);
    set_ints(&mut memory, 0, &eu);
    set_ints(&mut memory, 1, &ev);
    set_ints(&mut memory, 2, &ew);
    let mut dist = vec![SSSP_INF; g.n];
    dist[0] = 0;
    set_ints(&mut memory, 3, &dist);
    make(
        "sssp",
        &src,
        vec![Val::I(g.m as i64), Val::I(SSSP_ROUNDS)],
        memory,
        None,
    )
}

// ---------------------------------------------------------------------------
// bc — betweenness-centrality forward pass (path counting) of a single
// source, edge-sweep form: two guarded store families on two arrays
// (paper: "bc uses two LSQs"; chained if/else LoD as in Fig. 3).
//     for (r) for (e) { u,v;
//       if (d[u]>=0 && d[v]<0)      { d[v]=d[u]+1; sig[v]=sig[u]; }
//       else if (d[v]==d[u]+1)      { sig[v]+=sig[u]; } }
// ---------------------------------------------------------------------------

pub const BC_ROUNDS: i64 = 2;

pub fn bc(seed: u64) -> Workload {
    let g = graph::email_eu_core_like(seed);
    let (eu, ev, _) = graph::edge_list(&g, seed, 1);
    let src = format!(
        r#"
array @eu : i64[{m}]
array @ev : i64[{m}]
array @d : i64[{n}]
array @sig : i64[{n}]

func @bc(%m: i64, %rounds: i64) {{
entry:
  %c0 = const.i 0
  %c1 = const.i 1
  %cf = const.b false
  br rh
rh:
  %r = phi i64 [entry: %c0], [rlatch: %rnext]
  %cr = icmp.lt %r, %rounds
  condbr %cr, eh, exit
eh:
  %e = phi i64 [rh: %c0], [el: %enext]
  %ce = icmp.lt %e, %m
  condbr %ce, body, rlatch
body:
  %u = load @eu[%e]
  %v = load @ev[%e]
  %du = load @d[%u]
  %dv = load @d[%v]
  %su = load @sig[%u]
  %sv = load @sig[%v]
  %pa = icmp.ge %du, %c0
  %pb = icmp.lt %dv, %c0
  %p1 = select %pa, %pb, %cf
  condbr %p1, discover, elsebb
discover:
  %d1 = add.i %du, %c1
  store @d[%v], %d1
  store @sig[%v], %su
  br el
elsebb:
  %d1b = add.i %du, %c1
  %p2a = icmp.eq %dv, %d1b
  %p2 = select %pa, %p2a, %cf
  condbr %p2, accum, el
accum:
  %ns = add.i %sv, %su
  store @sig[%v], %ns
  br el
el:
  %enext = add.i %e, %c1
  br eh
rlatch:
  %rnext = add.i %r, %c1
  br rh
exit:
  ret
}}
"#,
        m = g.m,
        n = g.n
    );
    let module = parse_module(&src).unwrap();
    let mut memory = zero_memory(&module);
    set_ints(&mut memory, 0, &eu);
    set_ints(&mut memory, 1, &ev);
    let mut d = vec![-1i64; g.n];
    d[0] = 0;
    let mut sig = vec![0i64; g.n];
    sig[0] = 1;
    set_ints(&mut memory, 2, &d);
    set_ints(&mut memory, 3, &sig);
    make(
        "bc",
        &src,
        vec![Val::I(g.m as i64), Val::I(BC_ROUNDS)],
        memory,
        None,
    )
}

// ---------------------------------------------------------------------------
// independent Rust references
// ---------------------------------------------------------------------------

/// Recompute the expected final memory for a kernel with plain Rust code.
pub fn rust_reference(name: &str, init: &Memory, args: &[Val]) -> Memory {
    let mut mem = init.clone();
    match name {
        "hist" => {
            let n = args[0].as_i() as usize;
            let d = ints(&mem, 0);
            let mut h = ints(&mem, 1);
            for &v in d.iter().take(n) {
                if h[v as usize] < HIST_CAP {
                    h[v as usize] += 1;
                }
            }
            set_ints(&mut mem, 1, &h);
        }
        "thr" => {
            let n = args[0].as_i() as usize;
            let (mut r, mut g, mut b) = (ints(&mem, 0), ints(&mem, 1), ints(&mem, 2));
            for i in 0..n {
                if r[i] + g[i] + b[i] > THR_T {
                    r[i] = 0;
                    g[i] = 0;
                    b[i] = 0;
                }
            }
            set_ints(&mut mem, 0, &r);
            set_ints(&mut mem, 1, &g);
            set_ints(&mut mem, 2, &b);
        }
        "mm" => {
            let n = args[0].as_i() as usize;
            let (eu, ev) = (ints(&mem, 0), ints(&mem, 1));
            let mut mt = ints(&mem, 2);
            for i in 0..n {
                let (u, v) = (eu[i] as usize, ev[i] as usize);
                if mt[u] == -1 && mt[v] == -1 {
                    mt[u] = v as i64;
                    mt[v] = u as i64;
                }
            }
            set_ints(&mut mem, 2, &mt);
        }
        "fw" => {
            let n = args[0].as_i() as usize;
            let mut d = ints(&mem, 0);
            for k in 0..n {
                for i in 0..n {
                    for j in 0..n {
                        let s = d[i * n + k] + d[k * n + j];
                        if s < d[i * n + j] {
                            d[i * n + j] = s;
                        }
                    }
                }
            }
            set_ints(&mut mem, 0, &d);
        }
        "sort" => {
            let n = args[0].as_i() as usize;
            let mut a = ints(&mem, 0);
            let mut k = 2;
            while k <= n {
                let mut j = k / 2;
                while j > 0 {
                    for i in 0..n {
                        let l = i ^ j;
                        if l > i {
                            let up = (i & k) == 0;
                            if (up && a[i] > a[l]) || (!up && a[i] < a[l]) {
                                a.swap(i, l);
                            }
                        }
                    }
                    j /= 2;
                }
                k *= 2;
            }
            set_ints(&mut mem, 0, &a);
        }
        "spmv" => {
            let nnz = args[0].as_i() as usize;
            let (ri, ci, va, x) =
                (ints(&mem, 0), ints(&mem, 1), ints(&mem, 2), ints(&mem, 3));
            let mut y = ints(&mem, 4);
            for i in 0..nnz {
                let c = ci[i] as usize;
                if y[c] < SPMV_CAP {
                    y[c] += va[i] * x[ri[i] as usize];
                }
            }
            set_ints(&mut mem, 4, &y);
        }
        "bfs" => {
            let nv = args[0].as_i() as usize;
            let nlvl = args[1].as_i();
            let (rowp, col) = (ints(&mem, 0), ints(&mem, 1));
            let mut dist = ints(&mem, 2);
            for lvl in 0..nlvl {
                for u in 0..nv {
                    if dist[u] == lvl {
                        for e in rowp[u]..rowp[u + 1] {
                            let v = col[e as usize] as usize;
                            if dist[v] == -1 {
                                dist[v] = lvl + 1;
                            }
                        }
                    }
                }
            }
            set_ints(&mut mem, 2, &dist);
        }
        "sssp" => {
            let m = args[0].as_i() as usize;
            let rounds = args[1].as_i();
            let (eu, ev, ew) = (ints(&mem, 0), ints(&mem, 1), ints(&mem, 2));
            let mut dist = ints(&mem, 3);
            for _ in 0..rounds {
                for e in 0..m {
                    let nd = dist[eu[e] as usize] + ew[e];
                    if nd < dist[ev[e] as usize] {
                        dist[ev[e] as usize] = nd;
                    }
                }
            }
            set_ints(&mut mem, 3, &dist);
        }
        "bc" => {
            let m = args[0].as_i() as usize;
            let rounds = args[1].as_i();
            let (eu, ev) = (ints(&mem, 0), ints(&mem, 1));
            let mut d = ints(&mem, 2);
            let mut sig = ints(&mem, 3);
            for _ in 0..rounds {
                for e in 0..m {
                    let (u, v) = (eu[e] as usize, ev[e] as usize);
                    if d[u] >= 0 && d[v] < 0 {
                        d[v] = d[u] + 1;
                        sig[v] = sig[u];
                    } else if d[u] >= 0 && d[v] == d[u] + 1 {
                        sig[v] += sig[u];
                    }
                }
            }
            set_ints(&mut mem, 2, &d);
            set_ints(&mut mem, 3, &sig);
        }
        _ => panic!("no rust reference for {name}"),
    }
    mem
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{interpret, memory_diff};

    #[test]
    fn ir_matches_rust_reference_for_all_kernels() {
        for name in super::super::PAPER_KERNELS {
            let w = super::super::build(name, 12345, None).unwrap();
            let r = interpret(&w.module, &w.module.funcs[0], &w.args, w.memory.clone(), 50_000_000)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            let expect = rust_reference(name, &w.memory, &w.args);
            assert!(
                memory_diff(&r.memory, &expect).is_none(),
                "{name}: IR and Rust reference disagree at {:?}",
                memory_diff(&r.memory, &expect)
            );
        }
    }

    #[test]
    fn misspec_knobs_control_guard_rates() {
        // hist with rate r: fraction of iterations hitting saturated bins
        for &rate in &[0.0, 0.3, 0.8] {
            let w = hist(7, rate);
            let d = ints(&w.memory, 0);
            let h = ints(&w.memory, 1);
            let skipped =
                d.iter().filter(|&&v| h[v as usize] >= HIST_CAP).count() as f64 / d.len() as f64;
            assert!((skipped - rate).abs() < 0.06, "hist rate {rate} got {skipped}");
        }
        for &rate in &[0.2, 0.6, 1.0] {
            let w = thr(7, rate);
            let (r, g, b) = (ints(&w.memory, 0), ints(&w.memory, 1), ints(&w.memory, 2));
            let below = (0..THR_N)
                .filter(|&i| r[i] + g[i] + b[i] <= THR_T)
                .count() as f64
                / THR_N as f64;
            assert!((below - rate).abs() < 0.06, "thr rate {rate} got {below}");
        }
    }

    #[test]
    fn sort_sorts_monotone_runs() {
        let w = sort(3);
        let out = rust_reference("sort", &w.memory, &w.args);
        let a = ints(&out, 0);
        for i in 1..a.len() {
            assert!(a[i - 1] <= a[i], "not sorted at {i}");
        }
    }
}
