//! The paper's nine evaluation kernels (§8.1.2), the synthetic
//! email-Eu-core stand-in, and the Fig. 7 nested-if template.
//!
//! Each kernel is defined in the textual IR with the same loop/branch/
//! memory structure as the benchmark-suite C code the paper compiled
//! (reproduced in comments in `kernels.rs`), a seeded data generator
//! (with a mis-speculation-rate knob where Table 2 sweeps one), and an
//! independent plain-Rust reference implementation used to validate that
//! the IR encodes the intended algorithm.

pub mod graph;
pub mod kernels;
pub mod nested;

use crate::ir::types::Val;
use crate::ir::Module;
use crate::sim::Memory;
use anyhow::{bail, Result};

/// A runnable benchmark instance.
pub struct Workload {
    pub name: String,
    /// Module with the kernel as `funcs[0]`.
    pub module: Module,
    pub args: Vec<Val>,
    pub memory: Memory,
    /// The mis-speculation rate the generator aimed for (None = emergent
    /// from the data).
    pub target_misspec: Option<f64>,
}

/// Paper §8.1.2 kernel names, in Table 1 order.
pub const PAPER_KERNELS: [&str; 9] =
    ["bfs", "bc", "sssp", "hist", "thr", "mm", "fw", "sort", "spmv"];

/// Build a kernel by name with paper-default parameters.
/// `misspec` overrides the data generator's mis-speculation knob where
/// supported (hist, thr, mm, spmv — Table 2 sweeps the first three).
pub fn build(name: &str, seed: u64, misspec: Option<f64>) -> Result<Workload> {
    Ok(match name {
        "hist" => kernels::hist(seed, misspec.unwrap_or(0.02)),
        "thr" => kernels::thr(seed, misspec.unwrap_or(0.97)),
        "mm" => kernels::mm(seed, misspec.unwrap_or(0.31)),
        "fw" => kernels::fw(seed),
        "sort" => kernels::sort(seed),
        "spmv" => kernels::spmv(seed, misspec.unwrap_or(0.32)),
        "bfs" => kernels::bfs(seed),
        "sssp" => kernels::sssp(seed),
        "bc" => kernels::bc(seed),
        _ => bail!("unknown kernel {name} (expected one of {PAPER_KERNELS:?})"),
    })
}

/// All nine kernels with paper-default parameters.
pub fn paper_suite(seed: u64) -> Vec<Workload> {
    PAPER_KERNELS.iter().map(|n| build(n, seed, None).unwrap()).collect()
}

/// Independent Rust reference for a kernel; returns the expected final
/// memory. Panics on unknown kernels.
pub fn rust_reference(w: &Workload) -> Memory {
    kernels::rust_reference(&w.name, &w.memory, &w.args)
}

/// Helpers shared by the kernel builders.
pub(crate) fn ints(mem: &Memory, arr: usize) -> Vec<i64> {
    mem[arr].iter().map(|v| v.as_i()).collect()
}

pub(crate) fn set_ints(mem: &mut Memory, arr: usize, xs: &[i64]) {
    for (i, &x) in xs.iter().enumerate() {
        mem[arr][i] = Val::I(x);
    }
}
