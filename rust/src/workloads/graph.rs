//! Synthetic graph generator standing in for the paper's
//! `email-Eu-core` (1005 nodes, 25 571 directed edges) — no network
//! access in this environment, see DESIGN.md §2. The generator preserves
//! what drives the paper's measurements: node/edge counts and a skewed
//! (power-law-ish) degree distribution that yields irregular,
//! data-dependent access patterns and realistic mis-speculation rates.

use crate::util::Rng;

pub const EMAIL_EU_NODES: usize = 1005;
pub const EMAIL_EU_EDGES: usize = 25_571;

/// Compressed sparse row digraph.
#[derive(Clone, Debug)]
pub struct Csr {
    pub n: usize,
    pub m: usize,
    pub rowp: Vec<i64>,
    pub col: Vec<i64>,
}

impl Csr {
    pub fn out_degree(&self, u: usize) -> usize {
        (self.rowp[u + 1] - self.rowp[u]) as usize
    }
}

/// Power-law-ish random digraph with exactly `n` nodes and `m` edges.
pub fn synthetic(n: usize, m: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(m);
    // skewed endpoints; self-loops redrawn
    while edges.len() < m {
        let u = rng.zipf(n as u64, 4.0) as u32;
        let v = rng.below(n as u64) as u32;
        if u != v {
            edges.push((u, v));
        }
    }
    // ensure connectivity-ish: a spanning ring of light edges replaces the
    // first n entries' sources so BFS from node 0 reaches most nodes
    for (i, e) in edges.iter_mut().take(n - 1).enumerate() {
        *e = (i as u32, (i + 1) as u32);
    }
    rng.shuffle(&mut edges);

    let mut deg = vec![0i64; n];
    for &(u, _) in &edges {
        deg[u as usize] += 1;
    }
    let mut rowp = vec![0i64; n + 1];
    for i in 0..n {
        rowp[i + 1] = rowp[i] + deg[i];
    }
    let mut cursor = rowp.clone();
    let mut col = vec![0i64; m];
    for &(u, v) in &edges {
        col[cursor[u as usize] as usize] = v as i64;
        cursor[u as usize] += 1;
    }
    Csr { n, m, rowp, col }
}

/// The default stand-in for email-Eu-core.
pub fn email_eu_core_like(seed: u64) -> Csr {
    synthetic(EMAIL_EU_NODES, EMAIL_EU_EDGES, seed)
}

/// Flat edge list (u, v, w) with weights in `[1, max_w]`.
pub fn edge_list(g: &Csr, seed: u64, max_w: i64) -> (Vec<i64>, Vec<i64>, Vec<i64>) {
    let mut rng = Rng::new(seed ^ 0xE16E);
    let (mut eu, mut ev, mut ew) = (Vec::new(), Vec::new(), Vec::new());
    for u in 0..g.n {
        for e in g.rowp[u]..g.rowp[u + 1] {
            eu.push(u as i64);
            ev.push(g.col[e as usize]);
            ew.push(rng.range_i64(1, max_w + 1));
        }
    }
    (eu, ev, ew)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_edge_counts_match_email_eu_core() {
        let g = email_eu_core_like(1);
        assert_eq!(g.n, EMAIL_EU_NODES);
        assert_eq!(g.m, EMAIL_EU_EDGES);
        assert_eq!(*g.rowp.last().unwrap() as usize, g.m);
    }

    #[test]
    fn degree_distribution_is_skewed() {
        let g = email_eu_core_like(2);
        let mut degs: Vec<usize> = (0..g.n).map(|u| g.out_degree(u)).collect();
        degs.sort_unstable_by(|a, b| b.cmp(a));
        let top10: usize = degs.iter().take(10).sum();
        assert!(
            top10 * 10 > g.m,
            "top-10 nodes should carry >10% of edges, got {top10}/{}",
            g.m
        );
    }

    #[test]
    fn bfs_reaches_most_nodes() {
        let g = email_eu_core_like(3);
        let mut seen = vec![false; g.n];
        let mut q = vec![0usize];
        seen[0] = true;
        while let Some(u) = q.pop() {
            for e in g.rowp[u]..g.rowp[u + 1] {
                let v = g.col[e as usize] as usize;
                if !seen[v] {
                    seen[v] = true;
                    q.push(v);
                }
            }
        }
        let cnt = seen.iter().filter(|&&x| x).count();
        assert!(cnt > g.n * 9 / 10, "reached {cnt}/{}", g.n);
    }
}
