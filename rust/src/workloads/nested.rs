//! The Fig. 7 synthetic template: `n` nested if-levels, one store per
//! level, all guarded by a loaded value — SPEC inserts one poison block
//! per level and n(n+1)/2 poison calls (§8.3.1).
//!
//! ```text
//! for (i) { x = A[i];
//!   if (x > 0) { A[i] = x+1;
//!     if (x > 1) { A[i] = x+2;
//!       if (x > 2) { ... } } } }
//! ```
//!
//! The stores target the guarded array itself so every level carries the
//! paper's LoD control dependency.

use super::{set_ints, Workload};
use crate::ir::parser::parse_module;
use crate::ir::types::Val;
use crate::sim::zero_memory;
use crate::util::Rng;
use std::fmt::Write;

pub const NESTED_N: usize = 512;

/// Build the template with `levels` nested ifs (1..=8 in Fig. 7).
/// `depth_dist` controls the data: element values are uniform over
/// `[0, levels+1)`, so level k's store executes with probability
/// `(levels+1-k)/(levels+1)`.
pub fn nested(levels: usize, seed: u64) -> Workload {
    assert!((1..=16).contains(&levels));
    let mut src = String::new();
    let _ = writeln!(src, "array @A : i64[{NESTED_N}]");
    let _ = writeln!(src, "\nfunc @nested{levels}(%n: i64) {{");
    let _ = writeln!(src, "entry:\n  %c0 = const.i 0\n  br header");
    let _ = writeln!(
        src,
        "header:\n  %i = phi i64 [entry: %c0], [latch: %inext]\n  %cc = icmp.lt %i, %n\n  condbr %cc, body, exit"
    );
    let _ = writeln!(src, "body:\n  %x = load @A[%i]");
    // level 1 guard lives in body
    let _ = writeln!(src, "  %t0 = const.i 0\n  %p1 = icmp.gt %x, %t0\n  condbr %p1, lvl1, latch");
    for k in 1..=levels {
        let _ = writeln!(src, "lvl{k}:");
        let _ = writeln!(src, "  %v{k} = const.i {k}");
        let _ = writeln!(src, "  %s{k} = add.i %x, %v{k}");
        let _ = writeln!(src, "  store @A[%i], %s{k}");
        if k < levels {
            let _ = writeln!(src, "  %p{} = icmp.gt %x, %v{k}", k + 1);
            let _ = writeln!(src, "  condbr %p{}, lvl{}, latch", k + 1, k + 1);
        } else {
            let _ = writeln!(src, "  br latch");
        }
    }
    let _ = writeln!(
        src,
        "latch:\n  %c1 = const.i 1\n  %inext = add.i %i, %c1\n  br header"
    );
    let _ = writeln!(src, "exit:\n  ret\n}}");

    let module = parse_module(&src).unwrap_or_else(|e| panic!("nested{levels}: {e}"));
    let mut memory = zero_memory(&module);
    let mut rng = Rng::new(seed);
    let a: Vec<i64> = (0..NESTED_N).map(|_| rng.range_i64(0, levels as i64 + 1)).collect();
    set_ints(&mut memory, 0, &a);
    Workload {
        name: format!("nested{levels}"),
        module,
        args: vec![Val::I(NESTED_N as i64)],
        memory,
        target_misspec: None,
    }
}

/// Rust reference for the template.
pub fn nested_reference(levels: usize, w: &Workload) -> crate::sim::Memory {
    let mut mem = w.memory.clone();
    let mut a = super::ints(&mem, 0);
    for i in 0..NESTED_N {
        let x = a[i]; // guard value loaded once, before the stores
        for k in 1..=levels as i64 {
            if x > k - 1 {
                a[i] = x + k;
            } else {
                break;
            }
        }
    }
    set_ints(&mut mem, 0, &a);
    mem
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{interpret, memory_diff};

    #[test]
    fn nested_matches_reference_for_all_depths() {
        for levels in 1..=8 {
            let w = nested(levels, 99);
            let r = interpret(&w.module, &w.module.funcs[0], &w.args, w.memory.clone(), 10_000_000)
                .unwrap();
            let expect = nested_reference(levels, &w);
            assert!(
                memory_diff(&r.memory, &expect).is_none(),
                "nested{levels} mismatch"
            );
        }
    }

    #[test]
    fn spec_build_counts_scale_with_depth() {
        use crate::transform::{build, Arch, Compiled};
        let mut prev_calls = 0;
        for levels in 1..=4 {
            let w = nested(levels, 5);
            let c = build(&w.module, 0, Arch::Spec).unwrap();
            let Compiled::Dae { stats, .. } = &c else { panic!() };
            assert!(
                stats.poison_calls >= prev_calls,
                "poison calls should grow with nesting: {} then {}",
                prev_calls,
                stats.poison_calls
            );
            prev_calls = stats.poison_calls;
        }
    }
}
