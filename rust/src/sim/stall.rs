//! Structured stall diagnostics.
//!
//! When the machine stops making progress — a channel deadlock, the
//! instruction-budget safety valve, the no-timestamp-advance watchdog,
//! or the cooperative wall-clock timeout — the simulator returns a
//! [`StallDiagnostic`] carrying a snapshot of the machine state instead
//! of an opaque error string: per-unit control timestamps and dynamic
//! instruction counts, per-channel occupancy with last push/pop times,
//! and per-array LSQ fill. The error is an `anyhow` root cause, so
//! callers recover it with `err.downcast_ref::<StallDiagnostic>()`;
//! `coordinator::report::print_stall` renders it for the CLI.

use std::fmt;

/// Why the machine stopped.
#[derive(Clone, Debug)]
pub enum StallReason {
    /// No unit executed an instruction and no LSQ made progress, but
    /// work is still pending.
    Deadlock,
    /// A unit exceeded `MachineConfig::max_dyn_instrs`.
    InstrBudget { unit: String, limit: u64 },
    /// No unit timestamp or instruction count advanced for
    /// `MachineConfig::watchdog_rounds` consecutive scheduler rounds.
    Watchdog { rounds: u64 },
    /// The cooperative wall-clock budget (`MachineConfig::wall_timeout_ms`)
    /// expired mid-simulation.
    WallClock { ms: u64 },
}

impl fmt::Display for StallReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StallReason::Deadlock => write!(f, "deadlock (pending work, no unit can progress)"),
            StallReason::InstrBudget { unit, limit } => {
                write!(f, "unit {unit} exceeded max dynamic instructions ({limit})")
            }
            StallReason::Watchdog { rounds } => {
                write!(f, "watchdog: no timestamp advance for {rounds} scheduler rounds")
            }
            StallReason::WallClock { ms } => write!(f, "wall-clock timeout ({ms} ms) expired"),
        }
    }
}

/// One unit's state at stall time.
#[derive(Clone, Debug)]
pub struct UnitStat {
    pub unit: String,
    pub t_ctrl: u64,
    pub dyn_instrs: u64,
    pub done: bool,
}

/// One non-empty channel's state at stall time.
#[derive(Clone, Debug)]
pub struct ChannelStat {
    pub name: String,
    pub occupancy: usize,
    /// Timestamp of the most recent push / pop on the stream.
    pub last_push: u64,
    pub last_pop: u64,
}

/// One non-empty per-array LSQ's state at stall time.
#[derive(Clone, Debug)]
pub struct LsqStat {
    pub array: String,
    /// Admitted, unresolved requests in the window.
    pub window: usize,
    pub store_slots: usize,
    pub load_slots: usize,
}

#[derive(Clone, Debug)]
pub struct StallDiagnostic {
    pub reason: StallReason,
    pub units: Vec<UnitStat>,
    pub channels: Vec<ChannelStat>,
    pub lsqs: Vec<LsqStat>,
    /// Latest event timestamp when the stall was detected.
    pub max_t: u64,
    /// Telemetry snapshot at stall time (when the run had
    /// `MachineConfig::metrics`): per-unit blocked-cycle attribution
    /// and channel high-water marks, so the report says *where* the
    /// machine starved.
    pub metrics: Option<crate::metrics::MetricsSummary>,
}

impl StallDiagnostic {
    /// Full multi-line report (the CLI's verbose rendering; `Display`
    /// stays single-line so it embeds cleanly in an anyhow chain).
    pub fn render(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let _ = writeln!(s, "-- stall diagnostic: {} (max_t={}) --", self.reason, self.max_t);
        for u in &self.units {
            let _ = writeln!(
                s,
                "  unit {:<4} t_ctrl={:<10} dyn_instrs={:<12} done={}",
                u.unit, u.t_ctrl, u.dyn_instrs, u.done
            );
        }
        if self.channels.is_empty() {
            let _ = writeln!(s, "  channels: all empty");
        }
        for c in &self.channels {
            let _ = writeln!(
                s,
                "  chan {:<24} occupancy={:<6} last_push={:<10} last_pop={}",
                c.name, c.occupancy, c.last_push, c.last_pop
            );
        }
        for l in &self.lsqs {
            let _ = writeln!(
                s,
                "  lsq  @{:<23} window={:<9} store_slots={:<9} load_slots={}",
                l.array, l.window, l.store_slots, l.load_slots
            );
        }
        if let Some(ms) = &self.metrics {
            let _ = writeln!(s, "  -- starvation attribution (metrics snapshot) --");
            for u in &ms.units {
                let _ = writeln!(
                    s,
                    "  unit {:<4} blocked-on-pop={:<10} push-blocks={:<6} busy={}",
                    u.unit, u.blocked_pop_cycles, u.blocked_push_events, u.busy_instrs
                );
                for (chan, cyc) in &u.blocked_by {
                    let _ = writeln!(s, "       waited {cyc:>10} cycle(s) on {chan}");
                }
            }
            for c in &ms.channels {
                let _ = writeln!(
                    s,
                    "  hwm  {:<24} high-water={:<6} pushes={:<10} pops={}",
                    c.name, c.hwm, c.pushes, c.pops
                );
            }
        }
        s
    }
}

impl fmt::Display for StallDiagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let pending: usize = self.channels.iter().map(|c| c.occupancy).sum();
        write!(
            f,
            "machine stalled: {} [{} channel(s) pending, {} element(s); {} LSQ(s) non-empty; max_t={}]",
            self.reason,
            self.channels.len(),
            pending,
            self.lsqs.len(),
            self.max_t
        )
    }
}

impl std::error::Error for StallDiagnostic {}
