//! Pipeline event traces (Fig. 2 reproduction: decoupled vs.
//! non-decoupled address-generation timelines).
//!
//! Two renderings exist: [`Trace::render`] draws the ASCII timeline
//! below, and [`crate::metrics::perfetto::export`] (reachable as
//! `SimSession::perfetto` or `dae-spec profile --perfetto`) converts
//! the same events into a Chrome/Perfetto `trace_event` JSON document
//! — one lane per unit, instant events for poisons, plus counter
//! tracks for channel occupancy and decoupling slack when metrics are
//! enabled. Open the written file at <https://ui.perfetto.dev>.

#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Unit that produced the event (`agu`, `du`, `cu`, `sta`).
    pub unit: &'static str,
    /// Event kind (`send_ld`, `send_st`, `ld_issue`, `ld_done`,
    /// `st_commit`, `st_poison`, `consume`, `produce`).
    pub kind: &'static str,
    /// Static memory op id.
    pub mem: u32,
    /// Cycle of the event.
    pub t: u64,
}

#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
}

impl Trace {
    pub fn push(&mut self, unit: &'static str, kind: &'static str, mem: u32, t: u64) {
        self.events.push(TraceEvent { unit, kind, mem, t });
    }

    /// Render an ASCII timeline of the first `n` events per (unit, kind),
    /// bucketed by cycle — the Fig. 2 visualisation.
    pub fn render(&self, max_cycle: u64) -> String {
        use std::fmt::Write;
        let mut lanes: Vec<(String, Vec<u64>)> = Vec::new();
        for e in &self.events {
            if e.t > max_cycle {
                continue;
            }
            let lane = format!("{:>3} {:<9} m{}", e.unit, e.kind, e.mem);
            match lanes.iter_mut().find(|(l, _)| *l == lane) {
                Some((_, ts)) => ts.push(e.t),
                None => lanes.push((lane, vec![e.t])),
            }
        }
        let mut s = String::new();
        let _ = writeln!(s, "{:<20} | cycles 0..{max_cycle}", "lane");
        for (lane, ts) in &lanes {
            let mut row = vec![b'.'; (max_cycle + 1) as usize];
            for &t in ts {
                row[t as usize] = b'#';
            }
            let _ = writeln!(s, "{:<20} | {}", lane, String::from_utf8_lossy(&row));
        }
        s
    }
}
