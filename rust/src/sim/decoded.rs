//! Compile-time pre-decode of IR functions for the simulator hot path.
//!
//! The machine used to walk `Vec<InstrId>` per block, hash channel `Key`s
//! on every push/pop and linearly scan φ `incomings` on every block entry.
//! This module flattens all of that once, at `transform::build` time:
//!
//! - [`DecodedFn`] — a contiguous instruction stream per block with
//!   operand *slots* (`u32` indices into the unit's value file), branch
//!   targets as block indices, and per-predecessor φ-assignment tables so
//!   block entry is a table walk instead of an `incomings` scan.
//! - [`ChanTable`] — every channel the program can touch interned to a
//!   dense `u32` id (the simulator's `Channels` is a `Vec`, not a hash
//!   map), with per-array request/store-value ids and per-static-op
//!   load-value ids resolved into the instruction stream.
//!
//! Decode is deliberately *lenient* about malformed blocks: the verifier
//! skips unreachable blocks entirely (they may be unterminated or have
//! ill-formed φs), so such blocks decode to runtime traps ([`DTerm::
//! Unterminated`], [`DOp::PhiTrap`], missing φ tables) that only fire if
//! the block is actually executed — exactly matching the interpreter-style
//! engine this replaces.

use crate::ir::{BinOp, ChanKind, CmpOp, Function, Module, Op, Terminator};
use anyhow::{anyhow, Result};

/// Sentinel destination slot for ops without a result value.
pub const NO_DEST: u32 = u32::MAX;
/// Sentinel channel id ("no such channel registered").
pub const NO_CHAN: u32 = u32::MAX;

/// Channel role, mirroring the machine's former `Key` enum.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DChanKind {
    /// AGU → DU request stream (per array; loads + stores interleaved).
    Req,
    /// CU → DU store-value stream (per array — the ordering problem).
    StVal,
    /// DU → CU load-value sub-stream (per static op).
    LdVal,
    /// DU → AGU load-value sub-stream (per static op).
    LdValAgu,
}

/// Metadata for one interned channel (diagnostics + routing).
#[derive(Clone, Copy, Debug)]
pub struct ChanMeta {
    pub kind: DChanKind,
    /// Index into `Module::arrays`.
    pub arr: u32,
    /// Static memory-op tag (meaningful for `LdVal`/`LdValAgu` only).
    pub mem: u32,
}

/// Dense channel registry: every channel id the compiled program can
/// touch, interned at decode time.
#[derive(Clone, Debug, Default)]
pub struct ChanTable {
    pub metas: Vec<ChanMeta>,
    /// `Req` channel id per array (always allocated).
    pub req_of_arr: Vec<u32>,
    /// `StVal` channel id per array (always allocated).
    pub stval_of_arr: Vec<u32>,
    ldval: Vec<u32>,
    ldval_agu: Vec<u32>,
}

impl ChanTable {
    pub fn len(&self) -> usize {
        self.metas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.metas.is_empty()
    }

    /// Number of static memory-op tags (`mem` ids) in the program.
    pub fn n_mems(&self) -> usize {
        self.ldval.len()
    }

    /// DU → CU value channel for static op `mem`, if the CU consumes it.
    #[inline]
    pub fn ldval_of_mem(&self, mem: u32) -> Option<u32> {
        match self.ldval.get(mem as usize) {
            Some(&id) if id != NO_CHAN => Some(id),
            _ => None,
        }
    }

    /// DU → AGU value channel for static op `mem`, if the AGU consumes it.
    #[inline]
    pub fn ldval_agu_of_mem(&self, mem: u32) -> Option<u32> {
        match self.ldval_agu.get(mem as usize) {
            Some(&id) if id != NO_CHAN => Some(id),
            _ => None,
        }
    }

    fn alloc(&mut self, kind: DChanKind, arr: u32, mem: u32) -> u32 {
        let id = self.metas.len() as u32;
        self.metas.push(ChanMeta { kind, arr, mem });
        id
    }
}

/// A pre-decoded operation. Operands are `u32` slots into the unit's
/// value file; channels are dense [`ChanTable`] ids.
#[derive(Clone, Copy, Debug)]
pub enum DOp {
    ConstI(i64),
    ConstF(f64),
    ConstB(bool),
    IBin(BinOp, u32, u32),
    FBin(BinOp, u32, u32),
    ICmp(CmpOp, u32, u32),
    FCmp(CmpOp, u32, u32),
    Not(u32),
    Select { cond: u32, t: u32, f: u32 },
    IToF(u32),
    FToI(u32),
    /// STA-only direct memory access.
    Load { arr: u32, idx: u32 },
    /// STA-only direct memory access.
    Store { arr: u32, idx: u32, val: u32 },
    /// `send_ld_addr` / `send_st_addr` onto the array's request stream.
    Send { chan: u32, mem: u32, idx: u32, is_store: bool },
    Consume { chan: u32, mem: u32 },
    Produce { chan: u32, mem: u32, val: u32 },
    Poison { chan: u32, mem: u32, pred: Option<u32> },
    /// A φ past the leading φ group — malformed, but only an error if it
    /// is actually executed (the verifier skips unreachable blocks).
    PhiTrap,
}

#[derive(Clone, Copy, Debug)]
pub struct DInstr {
    pub op: DOp,
    /// Destination slot, or [`NO_DEST`].
    pub dest: u32,
}

/// Pre-decoded terminator with resolved block indices.
#[derive(Clone, Copy, Debug)]
pub enum DTerm {
    Br(u32),
    CondBr { cond: u32, t: u32, f: u32 },
    Ret,
    /// Runtime trap: executing this reproduces the engine's
    /// "unterminated block" error.
    Unterminated,
}

/// φ assignments for one predecessor of a block.
#[derive(Clone, Debug)]
pub struct PhiTable {
    /// Block index of the predecessor.
    pub pred: u32,
    /// `(dest slot, source slot)` per φ, in φ order. `None` marks a
    /// pred for which some φ has no incoming (ill-formed unreachable
    /// block) — entering from it raises the old runtime error.
    pub assigns: Option<Vec<(u32, u32)>>,
}

#[derive(Clone, Debug)]
pub struct DBlock {
    /// Per-predecessor φ tables (empty when the block has no φs).
    pub phis: Vec<PhiTable>,
    /// Whether the block has any leading φs (distinguishes "no φs" from
    /// "φs with no recorded predecessor").
    pub has_phis: bool,
    /// Non-φ instructions in execution order.
    pub instrs: Vec<DInstr>,
    pub term: DTerm,
}

/// A flattened function: 1:1 with `Function::blocks`, all ids resolved.
#[derive(Clone, Debug)]
pub struct DecodedFn {
    pub name: String,
    /// Value slots of the parameters, in order.
    pub params: Vec<u32>,
    /// Size of the value file.
    pub nvals: usize,
    pub entry: u32,
    pub blocks: Vec<DBlock>,
}

/// Everything the simulator needs, pre-decoded: the unit functions
/// (`[sta]` or `[agu, cu]`) plus the shared channel registry.
#[derive(Clone, Debug)]
pub struct DecodedSim {
    pub fns: Vec<DecodedFn>,
    pub chans: ChanTable,
}

/// Decode `m.funcs[i]` for each `i` in `fn_idxs` (pass `[0]` for a
/// monolithic build, `[agu, cu]` for a decoupled one) and intern every
/// channel the functions can touch.
pub fn decode_fns(m: &Module, fn_idxs: &[usize]) -> Result<DecodedSim> {
    let fns: Vec<&Function> = fn_idxs.iter().map(|&i| &m.funcs[i]).collect();
    let chans = build_chan_table(m, &fns);
    let mut dfns = Vec::with_capacity(fns.len());
    for f in &fns {
        dfns.push(decode_fn(m, f, &chans)?);
    }
    Ok(DecodedSim { fns: dfns, chans })
}

/// Intern the channel space. `Req`/`StVal` exist for every array (their
/// FIFOs start empty, so over-allocating is observationally neutral);
/// `LdVal`/`LdValAgu` are allocated per `consume_val` site, which makes
/// "channel registered" exactly equivalent to the old
/// `cu_consumes`/`agu_consumes` membership checks the DU routed by.
fn build_chan_table(m: &Module, fns: &[&Function]) -> ChanTable {
    let mut t = ChanTable::default();
    for ai in 0..m.arrays.len() {
        let id = t.alloc(DChanKind::Req, ai as u32, 0);
        t.req_of_arr.push(id);
        let id = t.alloc(DChanKind::StVal, ai as u32, 0);
        t.stval_of_arr.push(id);
    }
    let mut n_mems = 0usize;
    for f in fns {
        for b in &f.blocks {
            for &iid in &b.instrs {
                let mem = match &f.instr(iid).op {
                    Op::SendLdAddr { mem, .. }
                    | Op::SendStAddr { mem, .. }
                    | Op::ConsumeVal { mem, .. }
                    | Op::ProduceVal { mem, .. }
                    | Op::PoisonVal { mem, .. } => *mem,
                    _ => continue,
                };
                n_mems = n_mems.max(mem as usize + 1);
            }
        }
    }
    t.ldval = vec![NO_CHAN; n_mems];
    t.ldval_agu = vec![NO_CHAN; n_mems];
    for f in fns {
        for b in &f.blocks {
            for &iid in &b.instrs {
                if let Op::ConsumeVal { chan, mem, .. } = &f.instr(iid).op {
                    let arr = m.chan(*chan).arr.0;
                    let agu = matches!(m.chan(*chan).kind, ChanKind::LdValAgu);
                    let mi = *mem as usize;
                    let cur = if agu { t.ldval_agu[mi] } else { t.ldval[mi] };
                    if cur == NO_CHAN {
                        let kind = if agu { DChanKind::LdValAgu } else { DChanKind::LdVal };
                        let id = t.alloc(kind, arr, *mem);
                        if agu {
                            t.ldval_agu[mi] = id;
                        } else {
                            t.ldval[mi] = id;
                        }
                    }
                }
            }
        }
    }
    t
}

fn decode_fn(m: &Module, f: &Function, tbl: &ChanTable) -> Result<DecodedFn> {
    let mut blocks = Vec::with_capacity(f.blocks.len());
    for b in &f.blocks {
        // Leading φ group.
        let nphi = b
            .instrs
            .iter()
            .take_while(|&&iid| matches!(f.instr(iid).op, Op::Phi { .. }))
            .count();

        // Predecessor order: first appearance across the φ incomings.
        // (The engine only ever *indexes* by pred, so order is free; we
        // keep it deterministic for reproducible Debug output.)
        let mut pred_order: Vec<u32> = Vec::new();
        for &iid in &b.instrs[..nphi] {
            if let Op::Phi { incomings, .. } = &f.instr(iid).op {
                for (bb, _) in incomings {
                    if !pred_order.contains(&bb.0) {
                        pred_order.push(bb.0);
                    }
                }
            }
        }
        let mut phis: Vec<PhiTable> = Vec::with_capacity(pred_order.len());
        for &p in &pred_order {
            let mut assigns = Some(Vec::with_capacity(nphi));
            for &iid in &b.instrs[..nphi] {
                let instr = f.instr(iid);
                let Op::Phi { incomings, .. } = &instr.op else { unreachable!() };
                match incomings.iter().find(|(bb, _)| bb.0 == p) {
                    Some((_, v)) => {
                        if let Some(a) = assigns.as_mut() {
                            let dest = instr
                                .result
                                .ok_or_else(|| anyhow!("φ without result in @{}", f.name))?;
                            a.push((dest.0, v.0));
                        }
                    }
                    // Some φ lacks this pred: the table is unusable from
                    // that edge; only an error if dynamically taken.
                    None => assigns = None,
                }
            }
            phis.push(PhiTable { pred: p, assigns });
        }

        let mut instrs = Vec::with_capacity(b.instrs.len() - nphi);
        for &iid in &b.instrs[nphi..] {
            let instr = f.instr(iid);
            let dest = instr.result.map(|r| r.0).unwrap_or(NO_DEST);
            let op = match &instr.op {
                Op::Phi { .. } => DOp::PhiTrap,
                Op::ConstI(x) => DOp::ConstI(*x),
                Op::ConstF(x) => DOp::ConstF(*x),
                Op::ConstB(x) => DOp::ConstB(*x),
                Op::IBin(o, a, b) => DOp::IBin(*o, a.0, b.0),
                Op::FBin(o, a, b) => DOp::FBin(*o, a.0, b.0),
                Op::ICmp(o, a, b) => DOp::ICmp(*o, a.0, b.0),
                Op::FCmp(o, a, b) => DOp::FCmp(*o, a.0, b.0),
                Op::Not(a) => DOp::Not(a.0),
                Op::Select { cond, t, f: fv, .. } => {
                    DOp::Select { cond: cond.0, t: t.0, f: fv.0 }
                }
                Op::IToF(a) => DOp::IToF(a.0),
                Op::FToI(a) => DOp::FToI(a.0),
                Op::Load { arr, idx, .. } => DOp::Load { arr: arr.0, idx: idx.0 },
                Op::Store { arr, idx, val } => {
                    DOp::Store { arr: arr.0, idx: idx.0, val: val.0 }
                }
                Op::SendLdAddr { chan, mem, idx } => DOp::Send {
                    chan: tbl.req_of_arr[m.chan(*chan).arr.index()],
                    mem: *mem,
                    idx: idx.0,
                    is_store: false,
                },
                Op::SendStAddr { chan, mem, idx } => DOp::Send {
                    chan: tbl.req_of_arr[m.chan(*chan).arr.index()],
                    mem: *mem,
                    idx: idx.0,
                    is_store: true,
                },
                Op::ConsumeVal { chan, mem, .. } => {
                    let id = match m.chan(*chan).kind {
                        ChanKind::LdValAgu => tbl.ldval_agu_of_mem(*mem),
                        _ => tbl.ldval_of_mem(*mem),
                    }
                    .ok_or_else(|| {
                        anyhow!("decode: unregistered consume of m{} in @{}", mem, f.name)
                    })?;
                    DOp::Consume { chan: id, mem: *mem }
                }
                Op::ProduceVal { chan, mem, val } => DOp::Produce {
                    chan: tbl.stval_of_arr[m.chan(*chan).arr.index()],
                    mem: *mem,
                    val: val.0,
                },
                Op::PoisonVal { chan, mem, pred } => DOp::Poison {
                    chan: tbl.stval_of_arr[m.chan(*chan).arr.index()],
                    mem: *mem,
                    pred: pred.map(|p| p.0),
                },
            };
            instrs.push(DInstr { op, dest });
        }

        let term = match &b.term {
            Terminator::Br(t) => DTerm::Br(t.0),
            Terminator::CondBr { cond, t, f: fb } => {
                DTerm::CondBr { cond: cond.0, t: t.0, f: fb.0 }
            }
            Terminator::Ret => DTerm::Ret,
            Terminator::Unterminated => DTerm::Unterminated,
        };
        blocks.push(DBlock { phis, has_phis: nphi > 0, instrs, term });
    }
    Ok(DecodedFn {
        name: f.name.clone(),
        params: f.params.iter().map(|p| p.0).collect(),
        nvals: f.values.len(),
        entry: f.entry.0,
        blocks,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_module;
    use crate::transform::{build, Arch, Compiled};

    const SRC: &str = r#"
array @A : i64[64]
array @idx : i64[64]

func @fig1c(%n: i64) {
entry:
  %c0 = const.i 0
  br header
header:
  %i = phi i64 [entry: %c0], [latch: %inext]
  %cc = icmp.lt %i, %n
  condbr %cc, body, exit
body:
  %a = load @A[%i]
  %zero = const.i 0
  %p = icmp.gt %a, %zero
  condbr %p, then, latch
then:
  %w = load @idx[%i]
  %aw = load @A[%w]
  %c1 = const.i 1
  %fv = add.i %aw, %c1
  store @A[%w], %fv
  br latch
latch:
  %c1b = const.i 1
  %inext = add.i %i, %c1b
  br header
exit:
  ret
}
"#;

    #[test]
    fn decodes_monolithic_with_phi_tables() {
        let m = parse_module(SRC).unwrap();
        let d = decode_fns(&m, &[0]).unwrap();
        let f = &d.fns[0];
        assert_eq!(f.blocks.len(), m.funcs[0].blocks.len());
        assert_eq!(f.nvals, m.funcs[0].values.len());
        // block 1 is `header`: one φ with two incoming preds
        let header = &f.blocks[1];
        assert!(header.has_phis);
        assert_eq!(header.phis.len(), 2);
        for pt in &header.phis {
            assert_eq!(pt.assigns.as_ref().unwrap().len(), 1);
        }
        // non-φ streams skip the φs
        assert!(header.instrs.iter().all(|i| !matches!(i.op, DOp::PhiTrap)));
        // per-array Req/StVal always interned
        assert_eq!(d.chans.req_of_arr.len(), m.arrays.len());
        assert_eq!(d.chans.stval_of_arr.len(), m.arrays.len());
    }

    #[test]
    fn dense_ids_match_consume_sets() {
        let m = parse_module(SRC).unwrap();
        for arch in [Arch::Dae, Arch::Spec] {
            let c = build(&m, 0, arch).unwrap();
            let Compiled::Dae { program, decoded, .. } = &c else { panic!() };
            for mo in &program.mem_ops {
                if mo.is_store {
                    continue;
                }
                assert_eq!(
                    decoded.chans.ldval_of_mem(mo.mem).is_some(),
                    program.cu_consumes.contains(&mo.mem),
                    "{arch:?} m{} CU routing",
                    mo.mem
                );
                assert_eq!(
                    decoded.chans.ldval_agu_of_mem(mo.mem).is_some(),
                    program.agu_consumes.contains(&mo.mem),
                    "{arch:?} m{} AGU routing",
                    mo.mem
                );
            }
        }
    }
}
