//! Cycle-level timing model of the DAE machine, replacing the paper's
//! ModelSim RTL simulation (see DESIGN.md §2 for the substitution
//! argument).
//!
//! The model is a *timestamp-dataflow* simulation: the functional
//! co-simulation of the AGU, DU and CU drives control flow, and every
//! dynamic event (value definition, channel push/pop, LSQ entry, memory
//! port grant) carries a cycle timestamp computed from its dependencies:
//!
//! - pure ops: `t = max(operands) + latency`;
//! - side-effecting ops additionally wait for control resolution
//!   (`t_ctrl`, the running branch-resolution chain of the unit);
//! - channel pops wait for the matching push + channel latency, rate 1
//!   per cycle; a full FIFO (capacity `chan_cap`) blocks its producer
//!   host-side until a pop frees space (functional backpressure) —
//!   timestamps are data-driven, so capacity never changes timing;
//! - the per-array LSQ admits requests in arrival order, allocates store
//!   entries against the store-queue capacity (paper: 32), bounds load
//!   concurrency (paper: 4), forwards RAW through commit timestamps and
//!   drops poisoned stores without commit (§3.1);
//! - the dual-ported SRAM grants 1 read + 1 write per cycle per array.
//!
//! The statically-scheduled baseline (STA) runs the *same* engine with
//! memory executed in the single unit and the paper's conservative rule:
//! a load from an array may not issue before every earlier store to that
//! array has committed ("loads that cannot be disambiguated at compile
//! time execute in order", §8.1.1).
//!
//! Repeated-run consumers (bench timing loops, fuzz plan minimization)
//! should hold a [`SimSession`] — a reusable context allocated once per
//! `(Compiled, MachineConfig)` whose re-runs reset all machine state in
//! place and restore memory from a [`MemorySnapshot`] by memcpy, so the
//! steady state performs zero heap allocation. [`simulate`] is the
//! one-shot wrapper; results are bit-identical either way (pinned by
//! `rust/tests/determinism.rs`).

pub mod decoded;
pub mod interp;
pub mod machine;
pub mod session;
pub mod stall;
pub mod trace;

pub use decoded::{decode_fns, DecodedSim};
pub use interp::{interpret, InterpResult};
pub use machine::{simulate, simulate_checked, SimResult};
pub use session::{MemorySnapshot, RunStats, SimSession};
pub use stall::{ChannelStat, LsqStat, StallDiagnostic, StallReason, UnitStat};
pub use trace::{Trace, TraceEvent};

use crate::ir::types::Val;

/// Machine configuration. Defaults follow the paper's evaluation setup
/// (§8.1): on-chip dual-ported SRAM, LSQ load/store queue sizes 4/32.
#[derive(Clone, Debug)]
pub struct MachineConfig {
    /// SRAM read latency (cycles).
    pub mem_read_lat: u64,
    /// SRAM write occupancy (cycles until commit visible).
    pub mem_write_lat: u64,
    /// FIFO channel latency (cycles) — AGU→DU, DU→CU, CU→DU hops.
    pub chan_lat: u64,
    /// FIFO capacity (elements). A full channel blocks its producer
    /// until the consumer pops (functional backpressure); 0 means
    /// unbounded. Timing is unaffected — timestamps come from data
    /// dependencies, so the cap shapes host scheduling and the area
    /// model only.
    pub chan_cap: usize,
    /// LSQ load-queue size (max loads in flight per array). Paper: 4.
    pub ld_q: usize,
    /// LSQ store-queue size (max allocated store entries per array).
    /// Paper: 32.
    pub st_q: usize,
    /// Latency of integer/float multiply.
    pub mul_lat: u64,
    /// Latency of divide/remainder.
    pub div_lat: u64,
    /// Safety valve: abort after this many dynamic instructions per unit
    /// (returns a structured [`StallDiagnostic`] on trip).
    pub max_dyn_instrs: u64,
    /// Progress watchdog: abort with a [`StallDiagnostic`] when no unit
    /// timestamp or instruction count advances across this many
    /// consecutive scheduler rounds. 0 disables the watchdog.
    pub watchdog_rounds: u64,
    /// Cooperative wall-clock timeout in milliseconds, checked
    /// periodically inside the machine loop (so a wedged simulation
    /// terminates with a [`StallDiagnostic`] instead of hanging its
    /// runner thread). 0 disables the timeout.
    pub wall_timeout_ms: u64,
    /// Deterministic fault injection (latency spikes, channel jitter,
    /// LSQ squeezes — see [`crate::fault`]). `None` runs clean.
    pub fault: Option<crate::fault::FaultInjector>,
    /// Record a pipeline trace (Fig. 2 reproduction; also the event
    /// source of the Chrome/Perfetto exporter — see
    /// [`crate::metrics::perfetto`]).
    pub trace: bool,
    /// Collect decoupling telemetry (per-unit/channel/LSQ counters,
    /// decoupling slack, MLP — see [`crate::metrics`]). Off by
    /// default; collection is observation-only and never changes
    /// timing or results (pinned by `rust/tests/metrics.rs`).
    pub metrics: bool,
}

impl Default for MachineConfig {
    fn default() -> Self {
        MachineConfig {
            mem_read_lat: 2,
            mem_write_lat: 1,
            chan_lat: 2,
            chan_cap: 16,
            ld_q: 4,
            st_q: 32,
            mul_lat: 3,
            div_lat: 12,
            max_dyn_instrs: 200_000_000,
            watchdog_rounds: 10_000,
            wall_timeout_ms: 0,
            fault: None,
            trace: false,
            metrics: false,
        }
    }
}

/// Initial/final memory image: one value vector per array, index-aligned
/// with `Module::arrays`.
pub type Memory = Vec<Vec<Val>>;

/// Build a zeroed memory image for a module.
pub fn zero_memory(m: &crate::ir::Module) -> Memory {
    m.arrays
        .iter()
        .map(|a| vec![Val::zero(a.elem); a.size])
        .collect()
}

/// Bit-exact memory comparison; returns the first mismatch.
pub fn memory_diff(a: &Memory, b: &Memory) -> Option<(usize, usize)> {
    for (ai, (va, vb)) in a.iter().zip(b).enumerate() {
        for (i, (x, y)) in va.iter().zip(vb).enumerate() {
            if !x.bits_eq(*y) {
                return Some((ai, i));
            }
        }
    }
    None
}
