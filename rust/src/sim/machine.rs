//! The DAE machine: functional co-simulation of AGU + DU + CU (or the
//! single STA unit) with timestamp-dataflow timing. See `sim/mod.rs` for
//! the model description.

use super::interp::{clamp_idx, eval_fbin, eval_fcmp, eval_ibin, eval_icmp};
use super::stall::{ChannelStat, LsqStat, StallDiagnostic, StallReason, UnitStat};
use super::trace::Trace;
use super::{MachineConfig, Memory};
use crate::fault::FaultInjector;
use crate::ir::types::Val;
use crate::ir::{ArrayId, BlockId, ChanKind, Function, Module, Op, Terminator};
use crate::transform::{Arch, Compiled};
use anyhow::{anyhow, bail, Result};
use crate::util::FxHashMap;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

#[derive(Debug)]
pub struct SimResult {
    /// Total cycles: the latest timestamp of any event in the machine.
    pub cycles: u64,
    pub memory: Memory,
    pub dyn_instrs: u64,
    pub stores_committed: u64,
    pub stores_poisoned: u64,
    /// Store requests on speculated static ops.
    pub spec_store_reqs: u64,
    /// Poisons / speculative store requests (0 when nothing speculated).
    pub misspec_rate: f64,
    /// Per static op: (requests, poisons).
    pub per_mem: FxHashMap<u32, (u64, u64)>,
    pub trace: Option<Trace>,
    /// Committed stores in per-array stream order: (mem, addr, value).
    pub commit_log: Vec<(u32, i64, Val)>,
}

// ---------------------------------------------------------------------------
// channels
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
enum Key {
    /// AGU → DU request stream (per array; loads + stores interleaved).
    Req(ArrayId),
    /// CU → DU store-value stream (per array — the ordering problem).
    StVal(ArrayId),
    /// DU → CU load-value sub-stream (per static op).
    LdVal(ArrayId, u32),
    /// DU → AGU load-value sub-stream (per static op).
    LdValAgu(ArrayId, u32),
}

#[derive(Clone, Copy, Debug)]
struct Elem {
    val: Val,
    poison: bool,
    mem: u32,
    is_store: bool,
    /// Arrival time at the consumer.
    t: u64,
}

#[derive(Default)]
struct Chan {
    q: VecDeque<Elem>,
    last_push: u64,
    last_pop: u64,
}

#[derive(Default)]
struct Channels {
    map: FxHashMap<Key, Chan>,
}

impl Channels {
    fn push(&mut self, key: Key, mut e: Elem, lat: u64) {
        let c = self.map.entry(key).or_default();
        // 1 element/cycle on each stream
        let t_op = e.t.max(c.last_push + 1);
        c.last_push = t_op;
        e.t = t_op + lat;
        c.q.push_back(e);
    }

    fn front(&self, key: Key) -> Option<&Elem> {
        self.map.get(&key).and_then(|c| c.q.front())
    }

    /// Pop the raw element (admission path — no pop-rate accounting; the
    /// LSQ's in-order admission chain models that).
    fn pop_elem(&mut self, key: Key) -> Option<Elem> {
        self.map.get_mut(&key)?.q.pop_front()
    }

    fn pop(&mut self, key: Key, t_ctrl: u64) -> Option<(Val, bool, u32, u64)> {
        let c = self.map.get_mut(&key)?;
        let e = c.q.pop_front()?;
        let t = e.t.max(t_ctrl).max(c.last_pop + 1);
        c.last_pop = t;
        Some((e.val, e.poison, e.mem, t))
    }

    fn all_empty(&self) -> bool {
        self.map.values().all(|c| c.q.is_empty())
    }
}

// ---------------------------------------------------------------------------
// per-array LSQ (the DU)
// ---------------------------------------------------------------------------

#[derive(Clone)]
struct WinEntry {
    req: Elem,
    t_enter: u64,
    /// Per-(array, mem) admission sequence — value delivery is reordered
    /// back to this order (loads may execute out of order in the window,
    /// but the CU/AGU consume values in request order).
    seq: u64,
}

/// Per-static-op load-value reorder buffer (ring indexed by
/// `seq - next_release`; the window bounds its size).
#[derive(Default)]
struct Rob {
    next_admit: u64,
    next_release: u64,
    /// executed, not-yet-released values, slot i = seq `next_release + i`
    done: VecDeque<Option<(Val, u64)>>,
}

impl Rob {
    #[inline]
    fn insert(&mut self, seq: u64, v: (Val, u64)) {
        let idx = (seq - self.next_release) as usize;
        while self.done.len() <= idx {
            self.done.push_back(None);
        }
        self.done[idx] = Some(v);
    }

    #[inline]
    fn pop_ready(&mut self) -> Option<(Val, u64)> {
        match self.done.front() {
            Some(Some(_)) => {
                self.next_release += 1;
                self.done.pop_front().flatten()
            }
            _ => None,
        }
    }
}

struct Lsq {
    arr: ArrayId,
    /// LSQ window: admitted, unresolved requests in order.
    window: VecDeque<WinEntry>,
    /// Load-value reorder buffers, one per static load op.
    robs: FxHashMap<u32, Rob>,
    /// In-order admission time of the last request.
    t_enter_last: u64,
    /// Resolve times of allocated store entries (ring of ≤ st_q).
    store_slots: VecDeque<u64>,
    /// Completion times of in-flight loads (ring of ≤ ld_q).
    load_slots: VecDeque<u64>,
    /// Last commit time per address (RAW forwarding horizon).
    commit_at: FxHashMap<i64, u64>,
    read_port: u64,
    write_port: u64,
}

impl Lsq {
    fn new(arr: ArrayId) -> Self {
        Lsq {
            arr,
            window: VecDeque::new(),
            robs: FxHashMap::default(),
            t_enter_last: 0,
            store_slots: VecDeque::new(),
            load_slots: VecDeque::new(),
            commit_at: FxHashMap::default(),
            read_port: 0,
            write_port: 0,
        }
    }
}

// ---------------------------------------------------------------------------
// unit interpreter
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq)]
enum UnitKind {
    /// Monolithic STA unit (direct memory access).
    Sta,
    Agu,
    Cu,
}

struct Unit<'a> {
    kind: UnitKind,
    name: &'static str,
    f: &'a Function,
    env: Vec<Option<Val>>,
    tval: Vec<u64>,
    cur: BlockId,
    prev: Option<BlockId>,
    /// Next instruction index within the current block (φs handled on
    /// entry).
    pc: usize,
    entered: bool,
    t_ctrl: u64,
    done: bool,
    dyn_instrs: u64,
    // STA-only memory timing state
    sta_store_commit: FxHashMap<ArrayId, u64>,
    sta_read_port: FxHashMap<ArrayId, u64>,
    sta_write_port: FxHashMap<ArrayId, u64>,
}

enum StepOut {
    /// Made progress; call again.
    Progress,
    /// Waiting on a channel pop.
    Blocked,
    Done,
}

struct SimCtx<'a> {
    m: &'a Module,
    cfg: &'a MachineConfig,
    chans: Channels,
    memory: Memory,
    max_t: u64,
    agu_consumes: Vec<u32>,
    cu_consumes: Vec<u32>,
    trace: Option<Trace>,
    stores_committed: u64,
    stores_poisoned: u64,
    per_mem: FxHashMap<u32, (u64, u64)>,
    commit_log: Vec<(u32, i64, Val)>,
    /// Cooperative wall-clock deadline (from `cfg.wall_timeout_ms`).
    deadline: Option<Instant>,
}

impl SimCtx<'_> {
    fn bump(&mut self, t: u64) {
        if t > self.max_t {
            self.max_t = t;
        }
    }

    fn fault(&self) -> Option<&FaultInjector> {
        self.cfg.fault.as_ref()
    }

    /// Channel push latency at time `t`: base + injected jitter.
    fn push_lat(&self, t: u64) -> u64 {
        self.cfg.chan_lat + self.fault().map_or(0, |f| f.chan_push_delay(t))
    }

    fn read_lat(&self, t: u64) -> u64 {
        self.cfg.mem_read_lat + self.fault().map_or(0, |f| f.mem_read_extra(t))
    }

    fn write_lat(&self, t: u64) -> u64 {
        self.cfg.mem_write_lat + self.fault().map_or(0, |f| f.mem_write_extra(t))
    }

    /// Effective LSQ load-queue size at `t` (fault squeeze, floor 1).
    fn eff_ld_q(&self, t: u64) -> usize {
        self.fault().map_or(self.cfg.ld_q, |f| f.ld_q(self.cfg.ld_q, t))
    }

    /// Effective LSQ store-queue size at `t` (fault squeeze, floor 1).
    fn eff_st_q(&self, t: u64) -> usize {
        self.fault().map_or(self.cfg.st_q, |f| f.st_q(self.cfg.st_q, t))
    }

    fn over_deadline(&self) -> bool {
        matches!(self.deadline, Some(d) if Instant::now() >= d)
    }

    fn key_name(&self, k: &Key) -> String {
        match k {
            Key::Req(a) => format!("req(@{})", self.m.array(*a).name),
            Key::StVal(a) => format!("stval(@{})", self.m.array(*a).name),
            Key::LdVal(a, mem) => format!("ldval(@{},m{})", self.m.array(*a).name, mem),
            Key::LdValAgu(a, mem) => format!("ldval_agu(@{},m{})", self.m.array(*a).name, mem),
        }
    }

    /// Snapshot of every non-empty channel, for stall diagnostics.
    fn chan_stats(&self) -> Vec<ChannelStat> {
        let mut v: Vec<ChannelStat> = self
            .chans
            .map
            .iter()
            .filter(|(_, c)| !c.q.is_empty())
            .map(|(k, c)| ChannelStat {
                name: self.key_name(k),
                occupancy: c.q.len(),
                last_push: c.last_push,
                last_pop: c.last_pop,
            })
            .collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    fn stall_error(
        &self,
        reason: StallReason,
        units: Vec<UnitStat>,
        lsqs: Vec<LsqStat>,
    ) -> anyhow::Error {
        anyhow::Error::new(StallDiagnostic {
            reason,
            units,
            channels: self.chan_stats(),
            lsqs,
            max_t: self.max_t,
        })
    }
}

fn deadline_from(cfg: &MachineConfig) -> Option<Instant> {
    (cfg.wall_timeout_ms > 0).then(|| Instant::now() + Duration::from_millis(cfg.wall_timeout_ms))
}

impl<'a> Unit<'a> {
    fn new(kind: UnitKind, name: &'static str, f: &'a Function, args: &[Val]) -> Self {
        let mut env = vec![None; f.values.len()];
        for (i, &p) in f.params.iter().enumerate() {
            env[p.index()] = Some(args[i]);
        }
        Unit {
            kind,
            name,
            f,
            env,
            tval: vec![0; f.values.len()],
            cur: f.entry,
            prev: None,
            pc: 0,
            entered: false,
            t_ctrl: 0,
            done: false,
            dyn_instrs: 0,
            sta_store_commit: FxHashMap::default(),
            sta_read_port: FxHashMap::default(),
            sta_write_port: FxHashMap::default(),
        }
    }

    fn stat(&self) -> UnitStat {
        UnitStat {
            unit: self.name.to_string(),
            t_ctrl: self.t_ctrl,
            dyn_instrs: self.dyn_instrs,
            done: self.done,
        }
    }

    /// Execute until blocked on a channel or done. Returns whether any
    /// instruction was executed.
    fn run(&mut self, ctx: &mut SimCtx) -> Result<bool> {
        let mut any = false;
        loop {
            match self.step(ctx)? {
                StepOut::Progress => any = true,
                StepOut::Blocked => return Ok(any),
                StepOut::Done => {
                    self.done = true;
                    return Ok(any);
                }
            }
        }
    }

    fn step(&mut self, ctx: &mut SimCtx) -> Result<StepOut> {
        if self.done {
            return Ok(StepOut::Done);
        }
        let f = self.f;
        let block = &f.blocks[self.cur.index()];

        if !self.entered {
            // φs evaluate atomically on entry
            let mut updates: Vec<(usize, Val, u64)> = Vec::new();
            for &iid in &block.instrs {
                let instr = f.instr(iid);
                if let Op::Phi { incomings, .. } = &instr.op {
                    let pb = self.prev.ok_or_else(|| anyhow!("φ in entry block"))?;
                    let (_, v) = incomings
                        .iter()
                        .find(|(bb, _)| *bb == pb)
                        .ok_or_else(|| {
                            anyhow!("φ missing incoming for {pb} in {} of @{}", block.name, f.name)
                        })?;
                    let val = self.env[v.index()]
                        .ok_or_else(|| anyhow!("φ operand undefined in @{}", f.name))?;
                    let t = self.tval[v.index()].max(self.t_ctrl);
                    updates.push((instr.result.unwrap().index(), val, t));
                } else {
                    break;
                }
            }
            self.pc = updates.len();
            for (vi, val, t) in updates {
                self.env[vi] = Some(val);
                self.tval[vi] = t;
            }
            self.entered = true;
        }

        // straight-line execution from pc
        while self.pc < block.instrs.len() {
            let iid = block.instrs[self.pc];
            let instr = f.instr(iid);
            self.dyn_instrs += 1;
            if self.dyn_instrs > ctx.cfg.max_dyn_instrs {
                return Err(ctx
                    .stall_error(
                        StallReason::InstrBudget {
                            unit: self.name.to_string(),
                            limit: ctx.cfg.max_dyn_instrs,
                        },
                        vec![self.stat()],
                        vec![],
                    )
                    .context(format!("@{}: exceeded max dynamic instructions", f.name)));
            }
            if self.dyn_instrs & 0x3FF == 0 && ctx.over_deadline() {
                return Err(ctx.stall_error(
                    StallReason::WallClock { ms: ctx.cfg.wall_timeout_ms },
                    vec![self.stat()],
                    vec![],
                ));
            }

            macro_rules! get {
                ($v:expr) => {
                    self.env[$v.index()]
                        .ok_or_else(|| anyhow!("use of undefined value in @{}", f.name))?
                };
            }
            macro_rules! tv {
                ($v:expr) => {
                    self.tval[$v.index()]
                };
            }

            let (result, t_res): (Option<Val>, u64) = match &instr.op {
                Op::Phi { .. } => bail!("φ after non-φ reached execution in @{}", f.name),
                // constants are hardwired — available at t=0
                Op::ConstI(x) => (Some(Val::I(*x)), 0),
                Op::ConstF(x) => (Some(Val::F(*x)), 0),
                Op::ConstB(x) => (Some(Val::B(*x)), 0),
                Op::IBin(o, a, b) => {
                    let lat = match o {
                        crate::ir::BinOp::Mul => ctx.cfg.mul_lat,
                        crate::ir::BinOp::Div | crate::ir::BinOp::Rem => ctx.cfg.div_lat,
                        _ => 1,
                    };
                    (
                        Some(Val::I(eval_ibin(*o, get!(a).as_i(), get!(b).as_i()))),
                        tv!(a).max(tv!(b)) + lat,
                    )
                }
                Op::FBin(o, a, b) => {
                    let lat = match o {
                        crate::ir::BinOp::Mul => ctx.cfg.mul_lat,
                        crate::ir::BinOp::Div | crate::ir::BinOp::Rem => ctx.cfg.div_lat,
                        _ => 2,
                    };
                    (
                        Some(Val::F(eval_fbin(*o, get!(a).as_f(), get!(b).as_f()))),
                        tv!(a).max(tv!(b)) + lat,
                    )
                }
                Op::ICmp(o, a, b) => (
                    Some(Val::B(eval_icmp(*o, get!(a).as_i(), get!(b).as_i()))),
                    tv!(a).max(tv!(b)) + 1,
                ),
                Op::FCmp(o, a, b) => (
                    Some(Val::B(eval_fcmp(*o, get!(a).as_f(), get!(b).as_f()))),
                    tv!(a).max(tv!(b)) + 1,
                ),
                Op::Not(a) => (Some(Val::B(!get!(a).as_b())), tv!(a) + 1),
                Op::Select { cond, t, f: fv, .. } => {
                    let v = if get!(cond).as_b() { get!(t) } else { get!(fv) };
                    (Some(v), tv!(cond).max(tv!(t)).max(tv!(fv)) + 1)
                }
                Op::IToF(a) => (Some(Val::F(get!(a).as_i() as f64)), tv!(a) + 1),
                Op::FToI(a) => (Some(Val::I(get!(a).as_f() as i64)), tv!(a) + 1),

                Op::Load { arr, idx, .. } => {
                    // STA unit only
                    debug_assert!(self.kind == UnitKind::Sta);
                    let i = get!(idx).as_i();
                    let a = &ctx.memory[arr.index()];
                    if i < 0 || i as usize >= a.len() {
                        bail!("STA load @{}[{}] out of bounds", ctx.m.array(*arr).name, i);
                    }
                    let v = a[i as usize];
                    let barrier = self.sta_store_commit.get(arr).copied().unwrap_or(0);
                    let port = self.sta_read_port.entry(*arr).or_insert(0);
                    let t_issue = tv!(idx).max(self.t_ctrl).max(barrier).max(*port);
                    *port = t_issue + 1;
                    let t_done = t_issue + ctx.read_lat(t_issue);
                    ctx.bump(t_done);
                    if let Some(tr) = &mut ctx.trace {
                        tr.push("sta", "ld_issue", 0, t_issue);
                    }
                    (Some(v), t_done)
                }
                Op::Store { arr, idx, val } => {
                    debug_assert!(self.kind == UnitKind::Sta);
                    let i = get!(idx).as_i();
                    let v = get!(val);
                    let alen = ctx.memory[arr.index()].len();
                    if i < 0 || i as usize >= alen {
                        bail!("STA store @{}[{}] out of bounds", ctx.m.array(*arr).name, i);
                    }
                    let port = self.sta_write_port.entry(*arr).or_insert(0);
                    let t_w = tv!(idx).max(tv!(val)).max(self.t_ctrl).max(*port);
                    *port = t_w + 1;
                    let t_commit = t_w + ctx.write_lat(t_w);
                    ctx.memory[arr.index()][i as usize] = v;
                    ctx.commit_log.push((0, i, v));
                    let e = self.sta_store_commit.entry(*arr).or_insert(0);
                    *e = (*e).max(t_commit);
                    ctx.stores_committed += 1;
                    ctx.bump(t_commit);
                    if let Some(tr) = &mut ctx.trace {
                        tr.push("sta", "st_commit", 0, t_w);
                    }
                    (None, t_commit)
                }

                Op::SendLdAddr { chan, mem, idx } | Op::SendStAddr { chan, mem, idx } => {
                    let is_store = matches!(instr.op, Op::SendStAddr { .. });
                    let arr = ctx.m.chan(*chan).arr;
                    let t = tv!(idx).max(self.t_ctrl);
                    let lat = ctx.push_lat(t);
                    ctx.chans.push(
                        Key::Req(arr),
                        Elem { val: get!(idx), poison: false, mem: *mem, is_store, t },
                        lat,
                    );
                    ctx.bump(t);
                    if let Some(tr) = &mut ctx.trace {
                        tr.push(self.name, if is_store { "send_st" } else { "send_ld" }, *mem, t);
                    }
                    (None, t)
                }
                Op::ConsumeVal { chan, mem, .. } => {
                    let arr = ctx.m.chan(*chan).arr;
                    let key = match ctx.m.chan(*chan).kind {
                        ChanKind::LdValAgu => Key::LdValAgu(arr, *mem),
                        _ => Key::LdVal(arr, *mem),
                    };
                    // A stall-forever fault wedges the consume even though
                    // its operand has arrived (watchdog/deadlock testing).
                    if let Some(front) = ctx.chans.front(key) {
                        if ctx.fault().is_some_and(|fi| fi.wedge_consume(front.t)) {
                            return Ok(StepOut::Blocked);
                        }
                    }
                    // Dataflow pop: stream pops are in-order and (in these
                    // slices) unconditional per iteration, so the circuit
                    // pops ahead of branch resolution — no t_ctrl term.
                    let Some((v, _poison, _m, t)) = ctx.chans.pop(key, 0) else {
                        return Ok(StepOut::Blocked);
                    };
                    let t = t + ctx.fault().map_or(0, |fi| fi.chan_pop_stall(t));
                    ctx.bump(t);
                    if let Some(tr) = &mut ctx.trace {
                        tr.push(self.name, "consume", *mem, t);
                    }
                    (Some(v), t)
                }
                Op::ProduceVal { chan, mem, val } => {
                    let arr = ctx.m.chan(*chan).arr;
                    let t = tv!(val).max(self.t_ctrl);
                    let lat = ctx.push_lat(t);
                    ctx.chans.push(
                        Key::StVal(arr),
                        Elem { val: get!(val), poison: false, mem: *mem, is_store: true, t },
                        lat,
                    );
                    ctx.bump(t);
                    if let Some(tr) = &mut ctx.trace {
                        tr.push(self.name, "produce", *mem, t);
                    }
                    (None, t)
                }
                Op::PoisonVal { chan, mem, pred } => {
                    let fire = match pred {
                        Some(pv) => get!(pv).as_b(),
                        None => true,
                    };
                    let t = pred.map(|pv| tv!(pv)).unwrap_or(0).max(self.t_ctrl);
                    if fire {
                        let arr = ctx.m.chan(*chan).arr;
                        let lat = ctx.push_lat(t);
                        ctx.chans.push(
                            Key::StVal(arr),
                            Elem {
                                val: Val::I(0),
                                poison: true,
                                mem: *mem,
                                is_store: true,
                                t,
                            },
                            lat,
                        );
                        if let Some(tr) = &mut ctx.trace {
                            tr.push(self.name, "poison", *mem, t);
                        }
                    }
                    ctx.bump(t);
                    (None, t)
                }
            };

            if let (Some(r), Some(v)) = (instr.result, result) {
                self.env[r.index()] = Some(v);
                self.tval[r.index()] = t_res;
            }
            ctx.bump(t_res);
            self.pc += 1;
        }

        // terminator
        match &block.term {
            Terminator::Br(t) => {
                self.prev = Some(self.cur);
                self.cur = *t;
            }
            Terminator::CondBr { cond, t, f: fb } => {
                let c = self.env[cond.index()]
                    .ok_or_else(|| anyhow!("undefined branch condition in @{}", f.name))?;
                self.t_ctrl = self.t_ctrl.max(self.tval[cond.index()]);
                self.prev = Some(self.cur);
                self.cur = if c.as_b() { *t } else { *fb };
            }
            Terminator::Ret => return Ok(StepOut::Done),
            Terminator::Unterminated => bail!("unterminated block in @{}", f.name),
        }
        self.entered = false;
        self.pc = 0;
        Ok(StepOut::Progress)
    }
}

// ---------------------------------------------------------------------------
// the DU
// ---------------------------------------------------------------------------

/// Process as many requests as possible for one array. Returns whether
/// progress was made.
///
/// The LSQ window semantics (§3.1): requests are admitted in arrival
/// order; store *values* arrive in store order on the shared `StVal`
/// stream, so only the oldest unresolved store can resolve at a time;
/// loads may bypass value-pending stores but stall on an earlier
/// unresolved store to the same address (RAW). Poisoned stores release
/// their slot without committing.
fn du_step(lsq: &mut Lsq, ctx: &mut SimCtx) -> Result<bool> {
    let arr = lsq.arr;
    let mut progress = false;

    // admit everything that has arrived (fault squeezes shrink the
    // effective queue capacities, never below 1)
    while let Some(req) = ctx.chans.pop_elem(Key::Req(arr)) {
        let mut t_enter = req.t.max(lsq.t_enter_last + 1);
        if req.is_store {
            if lsq.store_slots.len() >= ctx.eff_st_q(t_enter) {
                t_enter = t_enter.max(lsq.store_slots.pop_front().unwrap());
            }
        } else if lsq.load_slots.len() >= ctx.eff_ld_q(t_enter) {
            t_enter = t_enter.max(lsq.load_slots.pop_front().unwrap());
        }
        lsq.t_enter_last = t_enter;
        ctx.per_mem.entry(req.mem).or_insert((0, 0)).0 += 1;
        let seq = if req.is_store {
            0
        } else {
            let rob = lsq.robs.entry(req.mem).or_default();
            let s = rob.next_admit;
            rob.next_admit += 1;
            s
        };
        lsq.window.push_back(WinEntry { req, t_enter, seq });
    }

    // process the window
    loop {
        let mut acted = false;
        let mut wi = 0;
        while wi < lsq.window.len() {
            let e = lsq.window[wi].clone();
            if e.req.is_store {
                // only the OLDEST unresolved store matches the next value
                let is_oldest_store = lsq
                    .window
                    .iter()
                    .take(wi)
                    .all(|x| !x.req.is_store);
                if !is_oldest_store {
                    wi += 1;
                    continue;
                }
                let Some(v) = ctx.chans.front(Key::StVal(arr)).copied() else {
                    wi += 1;
                    continue;
                };
                // Lemma 6.1 runtime check: the k-th store value must pair
                // with the k-th store request of this array's stream.
                if v.mem != e.req.mem {
                    bail!(
                        "store stream order violated on @{}: request m{} paired with value m{} \
                         (sequential consistency broken)",
                        ctx.m.array(arr).name,
                        e.req.mem,
                        v.mem
                    );
                }
                ctx.chans.pop(Key::StVal(arr), 0);
                // DropPoison is the deliberately-injected recovery bug:
                // the DU "loses" the poison bit and falls through to the
                // commit path, which the differential fuzz harness must
                // flag as a memory divergence.
                let poison_dropped =
                    v.poison && ctx.fault().is_some_and(|fi| fi.drop_poison(v.t));
                if v.poison && !poison_dropped {
                    let t_resolve = e.t_enter.max(v.t);
                    lsq.store_slots.push_back(t_resolve);
                    ctx.stores_poisoned += 1;
                    ctx.per_mem.get_mut(&e.req.mem).unwrap().1 += 1;
                    ctx.bump(t_resolve);
                    if let Some(tr) = &mut ctx.trace {
                        tr.push("du", "st_poison", e.req.mem, t_resolve);
                    }
                } else {
                    let addr = e.req.val.as_i();
                    let alen = ctx.memory[arr.index()].len();
                    if addr < 0 || addr as usize >= alen {
                        bail!(
                            "committed store @{}[{}] out of bounds (mem op m{})",
                            ctx.m.array(arr).name,
                            addr,
                            e.req.mem
                        );
                    }
                    let t_w = e.t_enter.max(v.t).max(lsq.write_port);
                    lsq.write_port = t_w + 1;
                    let t_commit = t_w + ctx.write_lat(t_w);
                    ctx.memory[arr.index()][addr as usize] = v.val;
                    ctx.commit_log.push((e.req.mem, addr, v.val));
                    lsq.commit_at.insert(addr, t_commit);
                    lsq.store_slots.push_back(t_commit);
                    ctx.stores_committed += 1;
                    ctx.bump(t_commit);
                    if let Some(tr) = &mut ctx.trace {
                        tr.push("du", "st_commit", e.req.mem, t_w);
                    }
                }
                lsq.window.remove(wi);
                acted = true;
                // restart the scan: removing the store may unblock loads
                break;
            } else {
                // load: stall only on an earlier unresolved same-address
                // store (disambiguation is exact — addresses are known at
                // admission)
                let addr = e.req.val.as_i();
                let raw_blocked = lsq
                    .window
                    .iter()
                    .take(wi)
                    .any(|x| x.req.is_store && x.req.val.as_i() == addr);
                if raw_blocked {
                    wi += 1;
                    continue;
                }
                let a = &ctx.memory[arr.index()];
                let v = a[clamp_idx(addr, a.len())];
                let raw = lsq.commit_at.get(&addr).copied().unwrap_or(0);
                let t_issue = e.t_enter.max(raw).max(lsq.read_port);
                lsq.read_port = t_issue + 1;
                let t_done = t_issue + ctx.read_lat(t_issue);
                ctx.bump(t_done);
                if let Some(tr) = &mut ctx.trace {
                    tr.push("du", "ld_issue", e.req.mem, t_issue);
                }
                lsq.load_slots.push_back(t_done);
                if lsq.load_slots.len() > ctx.eff_ld_q(t_done) {
                    lsq.load_slots.pop_front();
                }
                // deliver through the per-op reorder buffer: the consumer
                // pops values in request order even when loads bypass
                let mem = e.req.mem;
                lsq.robs.entry(mem).or_default().insert(e.seq, (v, t_done));
                loop {
                    let rob = lsq.robs.get_mut(&mem).unwrap();
                    let Some((rv, rt)) = rob.pop_ready() else { break };
                    let lat = ctx.push_lat(rt);
                    if ctx.cu_consumes.contains(&mem) {
                        ctx.chans.push(
                            Key::LdVal(arr, mem),
                            Elem { val: rv, poison: false, mem, is_store: false, t: rt },
                            lat,
                        );
                    }
                    if ctx.agu_consumes.contains(&mem) {
                        ctx.chans.push(
                            Key::LdValAgu(arr, mem),
                            Elem { val: rv, poison: false, mem, is_store: false, t: rt },
                            lat,
                        );
                    }
                }
                lsq.window.remove(wi);
                acted = true;
                break;
            }
        }
        if acted {
            progress = true;
        } else {
            break;
        }
    }
    Ok(progress)
}

/// Snapshot of every non-empty per-array LSQ, for stall diagnostics.
fn lsq_stats(lsqs: &[Lsq], m: &Module) -> Vec<LsqStat> {
    lsqs.iter()
        .filter(|l| !l.window.is_empty() || !l.store_slots.is_empty() || !l.load_slots.is_empty())
        .map(|l| LsqStat {
            array: m.array(l.arr).name.clone(),
            window: l.window.len(),
            store_slots: l.store_slots.len(),
            load_slots: l.load_slots.len(),
        })
        .collect()
}

// ---------------------------------------------------------------------------
// top level
// ---------------------------------------------------------------------------

/// Simulate a compiled architecture over `args` and an initial memory
/// image.
pub fn simulate(
    c: &Compiled,
    args: &[Val],
    memory: Memory,
    cfg: &MachineConfig,
) -> Result<SimResult> {
    match c {
        Compiled::Monolithic { module, .. } => {
            let f = &module.funcs[0];
            let mut ctx = SimCtx {
                m: module,
                cfg,
                chans: Channels::default(),
                memory,
                max_t: 0,
                agu_consumes: vec![],
                cu_consumes: vec![],
                trace: if cfg.trace { Some(Trace::default()) } else { None },
                stores_committed: 0,
                stores_poisoned: 0,
                per_mem: FxHashMap::default(),
                commit_log: Vec::new(),
                deadline: deadline_from(cfg),
            };
            let mut unit = Unit::new(UnitKind::Sta, "sta", f, args);
            loop {
                let progressed = unit.run(&mut ctx)?;
                if unit.done {
                    break;
                }
                if !progressed {
                    return Err(ctx
                        .stall_error(StallReason::Deadlock, vec![unit.stat()], vec![])
                        .context("STA unit blocked (channel op in monolithic build?)"));
                }
            }
            Ok(SimResult {
                cycles: ctx.max_t,
                memory: ctx.memory,
                dyn_instrs: unit.dyn_instrs,
                stores_committed: ctx.stores_committed,
                stores_poisoned: 0,
                spec_store_reqs: 0,
                misspec_rate: 0.0,
                per_mem: ctx.per_mem,
                trace: ctx.trace,
                commit_log: ctx.commit_log,
            })
        }
        Compiled::Dae { program, .. } => {
            let module = &program.module;
            let mut ctx = SimCtx {
                m: module,
                cfg,
                chans: Channels::default(),
                memory,
                max_t: 0,
                agu_consumes: program.agu_consumes.clone(),
                cu_consumes: program.cu_consumes.clone(),
                trace: if cfg.trace { Some(Trace::default()) } else { None },
                stores_committed: 0,
                stores_poisoned: 0,
                per_mem: FxHashMap::default(),
                commit_log: Vec::new(),
                deadline: deadline_from(cfg),
            };
            let spec_mems: Vec<u32> = c.speculated_mems();

            let mut agu = Unit::new(UnitKind::Agu, "agu", program.agu_fn(), args);
            let mut cu = Unit::new(UnitKind::Cu, "cu", program.cu_fn(), args);
            let mut lsqs: Vec<Lsq> = module
                .arrays
                .iter()
                .enumerate()
                .map(|(i, _)| Lsq::new(ArrayId(i as u32)))
                .collect();

            let mut rounds: u64 = 0;
            let mut stagnant: u64 = 0;
            let mut fingerprint: (u64, u64) = (0, 0);
            loop {
                let mut progress = false;
                if !agu.done {
                    progress |= agu.run(&mut ctx)?;
                }
                if !cu.done {
                    progress |= cu.run(&mut ctx)?;
                }
                for lsq in &mut lsqs {
                    progress |= du_step(lsq, &mut ctx)?;
                }
                if agu.done && cu.done && ctx.chans.all_empty()
                    && lsqs.iter().all(|l| l.window.is_empty())
                {
                    break;
                }
                if !progress {
                    return Err(ctx
                        .stall_error(
                            StallReason::Deadlock,
                            vec![agu.stat(), cu.stat()],
                            lsq_stats(&lsqs, ctx.m),
                        )
                        .context(format!(
                            "deadlock: agu_done={} cu_done={}",
                            agu.done, cu.done
                        )));
                }
                // Progress watchdog: scheduler rounds can report progress
                // (queue shuffling) without any timestamp or instruction
                // count advancing; bail with a diagnostic instead of
                // spinning toward max_dyn_instrs.
                rounds += 1;
                let fp = (ctx.max_t, agu.dyn_instrs + cu.dyn_instrs);
                if fp == fingerprint {
                    stagnant += 1;
                } else {
                    fingerprint = fp;
                    stagnant = 0;
                }
                if cfg.watchdog_rounds > 0 && stagnant >= cfg.watchdog_rounds {
                    return Err(ctx.stall_error(
                        StallReason::Watchdog { rounds: cfg.watchdog_rounds },
                        vec![agu.stat(), cu.stat()],
                        lsq_stats(&lsqs, ctx.m),
                    ));
                }
                if rounds & 0x3FF == 0 && ctx.over_deadline() {
                    return Err(ctx.stall_error(
                        StallReason::WallClock { ms: cfg.wall_timeout_ms },
                        vec![agu.stat(), cu.stat()],
                        lsq_stats(&lsqs, ctx.m),
                    ));
                }
            }

            let spec_store_reqs: u64 =
                spec_mems.iter().map(|m| ctx.per_mem.get(m).map(|x| x.0).unwrap_or(0)).sum();
            let spec_poisons: u64 =
                spec_mems.iter().map(|m| ctx.per_mem.get(m).map(|x| x.1).unwrap_or(0)).sum();
            Ok(SimResult {
                cycles: ctx.max_t,
                memory: ctx.memory,
                dyn_instrs: agu.dyn_instrs + cu.dyn_instrs,
                stores_committed: ctx.stores_committed,
                stores_poisoned: ctx.stores_poisoned,
                spec_store_reqs,
                misspec_rate: if spec_store_reqs > 0 {
                    spec_poisons as f64 / spec_store_reqs as f64
                } else {
                    0.0
                },
                per_mem: ctx.per_mem,
                trace: ctx.trace,
                commit_log: ctx.commit_log,
            })
        }
    }
}

/// Simulate and also return a functional cross-check against the
/// reference interpreter of the original function.
pub fn simulate_checked(
    m: &Module,
    func_idx: usize,
    c: &Compiled,
    args: &[Val],
    memory: Memory,
    cfg: &MachineConfig,
) -> Result<(SimResult, bool)> {
    let reference = super::interp::interpret(
        m,
        &m.funcs[func_idx],
        args,
        memory.clone(),
        cfg.max_dyn_instrs,
    )?;
    let sim = simulate(c, args, memory, cfg)?;
    let matches = super::memory_diff(&sim.memory, &reference.memory).is_none();
    let expected_match = !matches!(c.arch(), Arch::Oracle);
    if expected_match && !matches {
        let (ai, i) = super::memory_diff(&sim.memory, &reference.memory).unwrap();
        bail!(
            "{} final memory diverges from reference at @{}[{}]: {} vs {}",
            c.arch().name(),
            m.array(crate::ir::ArrayId(ai as u32)).name,
            i,
            sim.memory[ai][i],
            reference.memory[ai][i],
        );
    }
    Ok((sim, matches))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_module;
    use crate::sim::zero_memory;
    use crate::transform::{build, Arch};

    const FIG1C: &str = r#"
array @A : i64[64]
array @idx : i64[64]

func @fig1c(%n: i64) {
entry:
  %c0 = const.i 0
  br header
header:
  %i = phi i64 [entry: %c0], [latch: %inext]
  %cc = icmp.lt %i, %n
  condbr %cc, body, exit
body:
  %a = load @A[%i]
  %zero = const.i 0
  %p = icmp.gt %a, %zero
  condbr %p, then, latch
then:
  %w = load @idx[%i]
  %aw = load @A[%w]
  %c1 = const.i 1
  %fv = add.i %aw, %c1
  store @A[%w], %fv
  br latch
latch:
  %c1b = const.i 1
  %inext = add.i %i, %c1b
  br header
exit:
  ret
}
"#;

    fn fig1c_memory(m: &crate::ir::Module) -> Memory {
        let mut mem = zero_memory(m);
        for i in 0..64 {
            mem[0][i] = Val::I(if i % 3 == 0 { 5 } else { -5 });
            mem[1][i] = Val::I(((i * 7) % 64) as i64);
        }
        mem
    }

    #[test]
    fn sta_dae_spec_match_reference() {
        let m = parse_module(FIG1C).unwrap();
        let mem = fig1c_memory(&m);
        let cfg = MachineConfig::default();
        let mut cycles = std::collections::HashMap::new();
        for arch in [Arch::Sta, Arch::Dae, Arch::Spec] {
            let c = build(&m, 0, arch).unwrap();
            let (sim, ok) =
                simulate_checked(&m, 0, &c, &[Val::I(64)], mem.clone(), &cfg).unwrap();
            assert!(ok, "{arch:?} memory matches");
            cycles.insert(arch, sim.cycles);
            if arch == Arch::Spec {
                assert!(sim.stores_poisoned > 0, "some stores must be poisoned");
                assert!(sim.misspec_rate > 0.3 && sim.misspec_rate < 0.9);
            }
        }
        // the paper's shape: DAE (no spec) is much slower than SPEC
        assert!(
            cycles[&Arch::Dae] > 2 * cycles[&Arch::Spec],
            "DAE {} vs SPEC {}",
            cycles[&Arch::Dae],
            cycles[&Arch::Spec]
        );
        // and SPEC beats STA
        assert!(
            cycles[&Arch::Sta] > cycles[&Arch::Spec],
            "STA {} vs SPEC {}",
            cycles[&Arch::Sta],
            cycles[&Arch::Spec]
        );
    }

    #[test]
    fn oracle_runs_and_diverges_on_adversarial_data() {
        let m = parse_module(FIG1C).unwrap();
        let mem = fig1c_memory(&m);
        let cfg = MachineConfig::default();
        let c = build(&m, 0, Arch::Oracle).unwrap();
        let (sim, matches) =
            simulate_checked(&m, 0, &c, &[Val::I(64)], mem, &cfg).unwrap();
        assert!(!matches, "oracle must be functionally wrong on this input");
        assert!(sim.cycles > 0);
    }

    #[test]
    fn wedged_machine_reports_stall_diagnostic() {
        use crate::fault::{FaultInjector, FaultPlan};
        let m = parse_module(FIG1C).unwrap();
        let mem = fig1c_memory(&m);
        // Stall-forever fault: every ConsumeVal blocks, so the machine
        // must terminate via the structured deadlock path, not hang.
        let cfg = MachineConfig {
            fault: Some(FaultInjector::new(FaultPlan::wedge())),
            ..MachineConfig::default()
        };
        let c = build(&m, 0, Arch::Dae).unwrap();
        let err = simulate(&c, &[Val::I(64)], mem, &cfg).unwrap_err();
        let diag = err
            .downcast_ref::<StallDiagnostic>()
            .expect("wedge must produce a StallDiagnostic root cause");
        assert!(matches!(diag.reason, StallReason::Deadlock));
        let pending: usize = diag.channels.iter().map(|ch| ch.occupancy).sum();
        assert!(pending > 0, "diagnostic must list stuck channel elements");
        assert!(!diag.units.is_empty());
        // the rendering names the channels so a human can read the report
        let rendered = diag.render();
        assert!(rendered.contains("stall diagnostic"));
        assert!(rendered.contains("chan "), "render lists channels:\n{rendered}");
    }

    #[test]
    fn instr_budget_reports_structured_diag() {
        let m = parse_module(FIG1C).unwrap();
        let mem = fig1c_memory(&m);
        let cfg = MachineConfig { max_dyn_instrs: 16, ..MachineConfig::default() };
        let c = build(&m, 0, Arch::Sta).unwrap();
        let err = simulate(&c, &[Val::I(64)], mem, &cfg).unwrap_err();
        let diag = err
            .downcast_ref::<StallDiagnostic>()
            .expect("budget trip must produce a StallDiagnostic root cause");
        match &diag.reason {
            StallReason::InstrBudget { unit, limit } => {
                assert_eq!(unit, "sta");
                assert_eq!(*limit, 16);
            }
            other => panic!("expected InstrBudget, got {other:?}"),
        }
        assert_eq!(diag.units.len(), 1);
        assert!(diag.units[0].dyn_instrs >= 16);
    }
}
