//! The DAE machine: functional co-simulation of AGU + DU + CU (or the
//! single STA unit) with timestamp-dataflow timing. See `sim/mod.rs` for
//! the model description.
//!
//! Hot-path layout (see [`super::decoded`]): units execute pre-decoded
//! instruction streams ([`DecodedFn`]) over a dense channel vector
//! indexed by [`ChanTable`] ids, and the decoupled scheduler is a
//! wake-list — a blocked unit or LSQ registers the channel event it
//! waits on and is only re-stepped when that event fires, in a fixed
//! deterministic order. Timing is unaffected: timestamps are computed
//! from data dependencies, never from host scheduling order.
//!
//! All per-run machine state (register files, channel FIFOs, LSQ rings,
//! stat vectors) lives in a reusable [`super::session::SimSession`];
//! every stateful type here carries a `reset` that restores the
//! freshly-constructed state without dropping buffer capacity, so a
//! session re-run performs no steady-state heap allocation. [`simulate`]
//! is a thin one-shot wrapper over the session.

use super::decoded::{ChanTable, DBlock, DChanKind, DOp, DTerm, DecodedFn, NO_DEST};
use super::interp::{clamp_idx, eval_fbin, eval_fcmp, eval_ibin, eval_icmp};
use super::session::SimSession;
use super::stall::{ChannelStat, LsqStat, StallDiagnostic, StallReason, UnitStat};
use super::trace::Trace;
use super::{MachineConfig, Memory};
use crate::fault::FaultInjector;
use crate::ir::types::Val;
use crate::ir::{BinOp, Module};
use crate::metrics::{ChanRole, Metrics, MetricsSummary, SummaryEnv};
use crate::transform::{Arch, Compiled};
use crate::util::FxHashMap;
use anyhow::{anyhow, bail, Result};
use std::collections::VecDeque;
use std::time::{Duration, Instant};

#[derive(Debug)]
pub struct SimResult {
    /// Total cycles: the latest timestamp of any event in the machine.
    pub cycles: u64,
    pub memory: Memory,
    pub dyn_instrs: u64,
    pub stores_committed: u64,
    pub stores_poisoned: u64,
    /// Store requests on speculated static ops.
    pub spec_store_reqs: u64,
    /// Poisons / speculative store requests (0 when nothing speculated).
    pub misspec_rate: f64,
    /// Per static op: (requests, poisons).
    pub per_mem: FxHashMap<u32, (u64, u64)>,
    pub trace: Option<Trace>,
    /// Committed stores in per-array stream order: (mem, addr, value).
    pub commit_log: Vec<(u32, i64, Val)>,
    /// Telemetry summary (`MachineConfig::metrics`; see [`crate::metrics`]).
    pub metrics: Option<MetricsSummary>,
}

// ---------------------------------------------------------------------------
// channels
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
struct Elem {
    val: Val,
    poison: bool,
    mem: u32,
    is_store: bool,
    /// Arrival time at the consumer.
    t: u64,
}

/// What a blocked entity is waiting for on a channel.
#[derive(Clone, Copy, Debug)]
pub(super) struct Wait {
    chan: u32,
    /// `true`: producer blocked on a full FIFO, needs a pop to free
    /// space. `false`: consumer blocked on an empty FIFO, needs a push.
    needs_pop: bool,
}

#[derive(Default)]
struct Chan {
    q: VecDeque<Elem>,
    last_push: u64,
    last_pop: u64,
    /// Entity bits to wake when an element is pushed.
    wake_on_push: u64,
    /// Entity bits to wake when an element is popped.
    wake_on_pop: u64,
}

/// Dense channel state, indexed by [`ChanTable`] id. Accumulates a wake
/// mask the scheduler drains after each entity step.
pub(super) struct Channels {
    chans: Vec<Chan>,
    /// Functional FIFO capacity (0 = unbounded). Blocks producers only;
    /// timestamps are data-driven and unaffected.
    cap: usize,
    woken: u64,
}

impl Channels {
    pub(super) fn new(n: usize, cap: usize) -> Self {
        Channels { chans: (0..n).map(|_| Chan::default()).collect(), cap, woken: 0 }
    }

    /// Restore the freshly-constructed state: every FIFO emptied, push/
    /// pop rate chains and wake masks zeroed. Queue capacity is retained
    /// so a session re-run pushes into already-allocated rings.
    pub(super) fn reset(&mut self) {
        for c in &mut self.chans {
            c.q.clear();
            c.last_push = 0;
            c.last_pop = 0;
            c.wake_on_push = 0;
            c.wake_on_pop = 0;
        }
        self.woken = 0;
    }

    #[inline]
    fn full(&self, id: u32) -> bool {
        self.cap != 0 && self.chans[id as usize].q.len() >= self.cap
    }

    /// Unconditional push (caller has checked capacity).
    fn push(&mut self, id: u32, mut e: Elem, lat: u64) {
        let c = &mut self.chans[id as usize];
        // 1 element/cycle on each stream
        let t_op = e.t.max(c.last_push + 1);
        c.last_push = t_op;
        e.t = t_op + lat;
        c.q.push_back(e);
        let w = std::mem::take(&mut c.wake_on_push);
        self.woken |= w;
    }

    /// Capacity-checked push; `false` means the FIFO is full and the
    /// producer must block.
    fn try_push(&mut self, id: u32, e: Elem, lat: u64) -> bool {
        if self.full(id) {
            return false;
        }
        self.push(id, e, lat);
        true
    }

    fn front(&self, id: u32) -> Option<&Elem> {
        self.chans[id as usize].q.front()
    }

    /// Current occupancy of channel `id` (metrics sampling).
    #[inline]
    fn len_of(&self, id: u32) -> usize {
        self.chans[id as usize].q.len()
    }

    /// `(front arrival time, last pop time)` — what `pop` is about to
    /// see; lets the metrics layer compute consumer wait without
    /// perturbing the pop itself.
    #[inline]
    fn pop_preview(&self, id: u32) -> Option<(u64, u64)> {
        let c = &self.chans[id as usize];
        c.q.front().map(|e| (e.t, c.last_pop))
    }

    /// Pop the raw element (admission path — no pop-rate accounting; the
    /// LSQ's in-order admission chain models that).
    fn pop_elem(&mut self, id: u32) -> Option<Elem> {
        let c = &mut self.chans[id as usize];
        let e = c.q.pop_front()?;
        let w = std::mem::take(&mut c.wake_on_pop);
        self.woken |= w;
        Some(e)
    }

    fn pop(&mut self, id: u32, t_ctrl: u64) -> Option<(Val, bool, u32, u64)> {
        let c = &mut self.chans[id as usize];
        let e = c.q.pop_front()?;
        let t = e.t.max(t_ctrl).max(c.last_pop + 1);
        c.last_pop = t;
        let w = std::mem::take(&mut c.wake_on_pop);
        self.woken |= w;
        Some((e.val, e.poison, e.mem, t))
    }

    pub(super) fn all_empty(&self) -> bool {
        self.chans.iter().all(|c| c.q.is_empty())
    }

    fn wait_for_push(&mut self, id: u32, bit: u64) {
        self.chans[id as usize].wake_on_push |= bit;
    }

    fn wait_for_pop(&mut self, id: u32, bit: u64) {
        self.chans[id as usize].wake_on_pop |= bit;
    }

    pub(super) fn register(&mut self, w: Wait, bit: u64) {
        if w.needs_pop {
            self.wait_for_pop(w.chan, bit);
        } else {
            self.wait_for_push(w.chan, bit);
        }
    }

    pub(super) fn take_woken(&mut self) -> u64 {
        std::mem::take(&mut self.woken)
    }
}

// ---------------------------------------------------------------------------
// per-array LSQ (the DU)
// ---------------------------------------------------------------------------

#[derive(Clone)]
pub(super) struct WinEntry {
    req: Elem,
    t_enter: u64,
    /// Per-(array, mem) admission sequence — value delivery is reordered
    /// back to this order (loads may execute out of order in the window,
    /// but the CU/AGU consume values in request order).
    seq: u64,
}

/// Per-static-op load-value reorder buffer (ring indexed by
/// `seq - next_release`; the window bounds its size).
#[derive(Clone, Default)]
struct Rob {
    next_admit: u64,
    next_release: u64,
    /// executed, not-yet-released values, slot i = seq `next_release + i`
    done: VecDeque<Option<(Val, u64)>>,
}

impl Rob {
    #[inline]
    fn insert(&mut self, seq: u64, v: (Val, u64)) {
        let idx = (seq - self.next_release) as usize;
        while self.done.len() <= idx {
            self.done.push_back(None);
        }
        self.done[idx] = Some(v);
    }

    /// The next in-order value, if it has executed (not yet released).
    #[inline]
    fn peek_ready(&self) -> Option<(Val, u64)> {
        self.done.front().copied().flatten()
    }

    #[inline]
    fn release(&mut self) {
        self.next_release += 1;
        self.done.pop_front();
    }
}

pub(super) struct Lsq {
    /// Index into `Module::arrays`.
    arr: u32,
    /// Scheduler entity bit of this LSQ.
    bit: u64,
    /// Dense id of this array's request stream.
    req_ch: u32,
    /// Dense id of this array's store-value stream.
    stval_ch: u32,
    /// LSQ window: admitted, unresolved requests in order.
    pub(super) window: VecDeque<WinEntry>,
    /// Load-value reorder buffers, indexed by static-op id.
    robs: Vec<Rob>,
    /// Static ops with a ready ROB head whose delivery is blocked on a
    /// full value channel (functional backpressure) — retried first.
    pending: Vec<u32>,
    /// In-order admission time of the last request.
    t_enter_last: u64,
    /// Resolve times of allocated store entries (ring of ≤ st_q).
    store_slots: VecDeque<u64>,
    /// Completion times of in-flight loads (ring of ≤ ld_q).
    load_slots: VecDeque<u64>,
    /// Last commit time per address (RAW forwarding horizon), dense over
    /// the array.
    commit_at: Vec<u64>,
    read_port: u64,
    write_port: u64,
}

impl Lsq {
    pub(super) fn new(arr: u32, bit: u64, tbl: &ChanTable, arr_len: usize) -> Self {
        Lsq {
            arr,
            bit,
            req_ch: tbl.req_of_arr[arr as usize],
            stval_ch: tbl.stval_of_arr[arr as usize],
            window: VecDeque::new(),
            robs: vec![Rob::default(); tbl.n_mems()],
            pending: Vec::new(),
            t_enter_last: 0,
            store_slots: VecDeque::new(),
            load_slots: VecDeque::new(),
            commit_at: vec![0; arr_len],
            read_port: 0,
            write_port: 0,
        }
    }

    /// Restore the state of `Lsq::new` without dropping ring/window
    /// capacity (zero-alloc session re-runs).
    pub(super) fn reset(&mut self) {
        self.window.clear();
        for rob in &mut self.robs {
            rob.next_admit = 0;
            rob.next_release = 0;
            rob.done.clear();
        }
        self.pending.clear();
        self.t_enter_last = 0;
        self.store_slots.clear();
        self.load_slots.clear();
        self.commit_at.fill(0);
        self.read_port = 0;
        self.write_port = 0;
    }
}

// ---------------------------------------------------------------------------
// unit interpreter
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq)]
pub(super) enum UnitKind {
    /// Monolithic STA unit (direct memory access).
    Sta,
    Agu,
    Cu,
}

pub(super) struct Unit<'a> {
    kind: UnitKind,
    name: &'static str,
    f: &'a DecodedFn,
    env: Vec<Option<Val>>,
    tval: Vec<u64>,
    cur: u32,
    prev: Option<u32>,
    /// Next instruction index within the current block (φs handled on
    /// entry).
    pc: usize,
    entered: bool,
    t_ctrl: u64,
    pub(super) done: bool,
    pub(super) dyn_instrs: u64,
    /// Scratch for atomic φ application on block entry.
    phi_buf: Vec<(u32, Val, u64)>,
    // STA-only memory timing state, dense per array
    sta_store_commit: Vec<u64>,
    sta_read_port: Vec<u64>,
    sta_write_port: Vec<u64>,
}

enum StepOut {
    /// Made progress; call again.
    Progress,
    /// Waiting on a channel event.
    Blocked(Wait),
    Done,
}

/// Per-run execution context: shared config plus *borrowed* mutable
/// state owned by the [`SimSession`] (so re-runs reuse every buffer).
/// Scalar counters live here and are folded into the session's
/// [`super::session::RunStats`] when the run finishes.
pub(super) struct SimCtx<'a> {
    pub(super) m: &'a Module,
    pub(super) tbl: &'a ChanTable,
    pub(super) cfg: &'a MachineConfig,
    pub(super) chans: &'a mut Channels,
    pub(super) memory: &'a mut Memory,
    pub(super) max_t: u64,
    pub(super) trace: &'a mut Option<Trace>,
    /// Telemetry collectors (`None` = metrics off; hooks cost one
    /// discriminant test). Observation-only: never feeds back into
    /// timing — pinned by `rust/tests/metrics.rs`.
    pub(super) metrics: &'a mut Option<Metrics>,
    /// Static mem-op ids of speculatively hoisted stores / loads
    /// (SPEC builds; empty otherwise) — summary attribution only.
    pub(super) spec_store_mems: &'a [u32],
    pub(super) spec_load_mems: &'a [u32],
    pub(super) stores_committed: u64,
    pub(super) stores_poisoned: u64,
    /// Per static op (dense by mem id): (requests, poisons).
    pub(super) per_mem: &'a mut [(u64, u64)],
    pub(super) commit_log: &'a mut Vec<(u32, i64, Val)>,
    /// Cooperative wall-clock deadline (from `cfg.wall_timeout_ms`).
    pub(super) deadline: Option<Instant>,
}

impl SimCtx<'_> {
    fn bump(&mut self, t: u64) {
        if t > self.max_t {
            self.max_t = t;
        }
    }

    fn fault(&self) -> Option<&FaultInjector> {
        self.cfg.fault.as_ref()
    }

    /// Channel push latency at time `t`: base + injected jitter.
    fn push_lat(&self, t: u64) -> u64 {
        self.cfg.chan_lat + self.fault().map_or(0, |f| f.chan_push_delay(t))
    }

    fn read_lat(&self, t: u64) -> u64 {
        self.cfg.mem_read_lat + self.fault().map_or(0, |f| f.mem_read_extra(t))
    }

    fn write_lat(&self, t: u64) -> u64 {
        self.cfg.mem_write_lat + self.fault().map_or(0, |f| f.mem_write_extra(t))
    }

    /// Extra STA read-port busy cycles at `t` (fault injection).
    fn sta_rd_port_extra(&self, t: u64) -> u64 {
        self.fault().map_or(0, |f| f.sta_read_port_extra(t))
    }

    /// Extra STA write-port busy cycles at `t` (fault injection).
    fn sta_wr_port_extra(&self, t: u64) -> u64 {
        self.fault().map_or(0, |f| f.sta_write_port_extra(t))
    }

    /// Effective LSQ load-queue size at `t` (fault squeeze, floor 1).
    fn eff_ld_q(&self, t: u64) -> usize {
        self.fault().map_or(self.cfg.ld_q, |f| f.ld_q(self.cfg.ld_q, t))
    }

    /// Effective LSQ store-queue size at `t` (fault squeeze, floor 1).
    fn eff_st_q(&self, t: u64) -> usize {
        self.fault().map_or(self.cfg.st_q, |f| f.st_q(self.cfg.st_q, t))
    }

    pub(super) fn over_deadline(&self) -> bool {
        matches!(self.deadline, Some(d) if Instant::now() >= d)
    }

    fn chan_name(&self, id: usize) -> String {
        chan_name(self.m, self.tbl, id)
    }

    /// Fold the raw metrics collectors into a [`MetricsSummary`]
    /// (`None` when metrics are off). Called at run end and when a
    /// stall diagnostic snapshots the machine.
    pub(super) fn metrics_summary(&self, units: &[UnitStat]) -> Option<MetricsSummary> {
        let met = self.metrics.as_ref()?;
        let unit_instrs: Vec<(String, u64)> =
            units.iter().map(|u| (u.unit.clone(), u.dyn_instrs)).collect();
        let env = SummaryEnv {
            cycles: self.max_t,
            units: &unit_instrs,
            chan_names: (0..self.tbl.len()).map(|i| chan_name(self.m, self.tbl, i)).collect(),
            chan_roles: self.tbl.metas.iter().map(|meta| chan_role(meta.kind)).collect(),
            array_names: self.m.arrays.iter().map(|a| a.name.clone()).collect(),
            per_mem: &*self.per_mem,
            spec_store_mems: self.spec_store_mems,
            spec_load_mems: self.spec_load_mems,
        };
        Some(met.summarize(&env))
    }

    /// Snapshot of every non-empty channel, for stall diagnostics.
    fn chan_stats(&self) -> Vec<ChannelStat> {
        let mut v: Vec<ChannelStat> = self
            .chans
            .chans
            .iter()
            .enumerate()
            .filter(|(_, c)| !c.q.is_empty())
            .map(|(id, c)| ChannelStat {
                name: self.chan_name(id),
                occupancy: c.q.len(),
                last_push: c.last_push,
                last_pop: c.last_pop,
            })
            .collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    pub(super) fn stall_error(
        &self,
        reason: StallReason,
        units: Vec<UnitStat>,
        lsqs: Vec<LsqStat>,
    ) -> anyhow::Error {
        let metrics = self.metrics_summary(&units);
        anyhow::Error::new(StallDiagnostic {
            reason,
            units,
            channels: self.chan_stats(),
            lsqs,
            max_t: self.max_t,
            metrics,
        })
    }
}

/// Human-readable channel name — shared by stall diagnostics, metrics
/// summaries and the Perfetto exporter.
pub(super) fn chan_name(m: &Module, tbl: &ChanTable, id: usize) -> String {
    let meta = &tbl.metas[id];
    let an = &m.arrays[meta.arr as usize].name;
    match meta.kind {
        DChanKind::Req => format!("req(@{an})"),
        DChanKind::StVal => format!("stval(@{an})"),
        DChanKind::LdVal => format!("ldval(@{an},m{})", meta.mem),
        DChanKind::LdValAgu => format!("ldval_agu(@{an},m{})", meta.mem),
    }
}

/// Static producer/consumer unit of each channel kind — lets the
/// metrics layer attribute blocked cycles per unit without runtime
/// unit ids.
pub(super) fn chan_role(kind: DChanKind) -> ChanRole {
    match kind {
        DChanKind::Req => ChanRole { producer: "agu", consumer: "du" },
        DChanKind::StVal => ChanRole { producer: "cu", consumer: "du" },
        DChanKind::LdVal => ChanRole { producer: "du", consumer: "cu" },
        DChanKind::LdValAgu => ChanRole { producer: "du", consumer: "agu" },
    }
}

pub(super) fn deadline_from(cfg: &MachineConfig) -> Option<Instant> {
    (cfg.wall_timeout_ms > 0).then(|| Instant::now() + Duration::from_millis(cfg.wall_timeout_ms))
}

impl<'a> Unit<'a> {
    /// Allocate a unit's state. The register file is unpopulated until
    /// [`Unit::reset`] installs the run's arguments.
    pub(super) fn new(
        kind: UnitKind,
        name: &'static str,
        f: &'a DecodedFn,
        n_arrays: usize,
    ) -> Self {
        Unit {
            kind,
            name,
            f,
            env: vec![None; f.nvals],
            tval: vec![0; f.nvals],
            cur: f.entry,
            prev: None,
            pc: 0,
            entered: false,
            t_ctrl: 0,
            done: false,
            dyn_instrs: 0,
            phi_buf: Vec::new(),
            sta_store_commit: vec![0; n_arrays],
            sta_read_port: vec![0; n_arrays],
            sta_write_port: vec![0; n_arrays],
        }
    }

    /// Rewind to the entry block with a fresh register file seeded from
    /// `args`. Buffer capacity is retained; no allocation.
    pub(super) fn reset(&mut self, args: &[Val]) {
        self.env.fill(None);
        for (i, &p) in self.f.params.iter().enumerate() {
            self.env[p as usize] = Some(args[i]);
        }
        self.tval.fill(0);
        self.cur = self.f.entry;
        self.prev = None;
        self.pc = 0;
        self.entered = false;
        self.t_ctrl = 0;
        self.done = false;
        self.dyn_instrs = 0;
        self.phi_buf.clear();
        self.sta_store_commit.fill(0);
        self.sta_read_port.fill(0);
        self.sta_write_port.fill(0);
    }

    pub(super) fn stat(&self) -> UnitStat {
        UnitStat {
            unit: self.name.to_string(),
            t_ctrl: self.t_ctrl,
            dyn_instrs: self.dyn_instrs,
            done: self.done,
        }
    }

    /// Execute until blocked on a channel event or done. Returns the wait
    /// condition when blocked.
    pub(super) fn run(&mut self, ctx: &mut SimCtx) -> Result<Option<Wait>> {
        loop {
            match self.step(ctx)? {
                StepOut::Progress => {}
                StepOut::Blocked(w) => return Ok(Some(w)),
                StepOut::Done => {
                    self.done = true;
                    return Ok(None);
                }
            }
        }
    }

    /// Apply the pre-decoded φ table for entry into `block` from
    /// `self.prev`. Reads all sources before writing (φs are atomic).
    fn enter_phis(&mut self, block: &DBlock, fname: &str) -> Result<()> {
        let prev = self.prev.ok_or_else(|| anyhow!("φ in entry block"))?;
        let assigns = block
            .phis
            .iter()
            .find(|p| p.pred == prev)
            .and_then(|p| p.assigns.as_ref())
            .ok_or_else(|| {
                anyhow!("φ missing incoming for bb{prev} in bb{} of @{fname}", self.cur)
            })?;
        self.phi_buf.clear();
        for &(dest, src) in assigns {
            let val = self.env[src as usize]
                .ok_or_else(|| anyhow!("φ operand undefined in @{fname}"))?;
            let t = self.tval[src as usize].max(self.t_ctrl);
            self.phi_buf.push((dest, val, t));
        }
        for &(dest, val, t) in &self.phi_buf {
            self.env[dest as usize] = Some(val);
            self.tval[dest as usize] = t;
        }
        Ok(())
    }

    fn step(&mut self, ctx: &mut SimCtx) -> Result<StepOut> {
        if self.done {
            return Ok(StepOut::Done);
        }
        let f = self.f;
        let block = &f.blocks[self.cur as usize];

        if !self.entered {
            if block.has_phis {
                self.enter_phis(block, &f.name)?;
            }
            self.pc = 0;
            self.entered = true;
        }

        // straight-line execution from pc
        while self.pc < block.instrs.len() {
            if self.dyn_instrs >= ctx.cfg.max_dyn_instrs {
                return Err(ctx
                    .stall_error(
                        StallReason::InstrBudget {
                            unit: self.name.to_string(),
                            limit: ctx.cfg.max_dyn_instrs,
                        },
                        vec![self.stat()],
                        vec![],
                    )
                    .context(format!("@{}: exceeded max dynamic instructions", f.name)));
            }
            if self.dyn_instrs & 0x3FF == 0 && ctx.over_deadline() {
                return Err(ctx.stall_error(
                    StallReason::WallClock { ms: ctx.cfg.wall_timeout_ms },
                    vec![self.stat()],
                    vec![],
                ));
            }
            let instr = block.instrs[self.pc];

            macro_rules! get {
                ($v:expr) => {
                    self.env[$v as usize]
                        .ok_or_else(|| anyhow!("use of undefined value in @{}", f.name))?
                };
            }
            macro_rules! tv {
                ($v:expr) => {
                    self.tval[$v as usize]
                };
            }

            let (result, t_res): (Option<Val>, u64) = match instr.op {
                DOp::PhiTrap => bail!("φ after non-φ reached execution in @{}", f.name),
                // constants are hardwired — available at t=0
                DOp::ConstI(x) => (Some(Val::I(x)), 0),
                DOp::ConstF(x) => (Some(Val::F(x)), 0),
                DOp::ConstB(x) => (Some(Val::B(x)), 0),
                DOp::IBin(o, a, b) => {
                    let lat = match o {
                        BinOp::Mul => ctx.cfg.mul_lat,
                        BinOp::Div | BinOp::Rem => ctx.cfg.div_lat,
                        _ => 1,
                    };
                    (
                        Some(Val::I(eval_ibin(o, get!(a).as_i(), get!(b).as_i()))),
                        tv!(a).max(tv!(b)) + lat,
                    )
                }
                DOp::FBin(o, a, b) => {
                    let lat = match o {
                        BinOp::Mul => ctx.cfg.mul_lat,
                        BinOp::Div | BinOp::Rem => ctx.cfg.div_lat,
                        _ => 2,
                    };
                    (
                        Some(Val::F(eval_fbin(o, get!(a).as_f(), get!(b).as_f()))),
                        tv!(a).max(tv!(b)) + lat,
                    )
                }
                DOp::ICmp(o, a, b) => (
                    Some(Val::B(eval_icmp(o, get!(a).as_i(), get!(b).as_i()))),
                    tv!(a).max(tv!(b)) + 1,
                ),
                DOp::FCmp(o, a, b) => (
                    Some(Val::B(eval_fcmp(o, get!(a).as_f(), get!(b).as_f()))),
                    tv!(a).max(tv!(b)) + 1,
                ),
                DOp::Not(a) => (Some(Val::B(!get!(a).as_b())), tv!(a) + 1),
                DOp::Select { cond, t, f: fv } => {
                    let v = if get!(cond).as_b() { get!(t) } else { get!(fv) };
                    (Some(v), tv!(cond).max(tv!(t)).max(tv!(fv)) + 1)
                }
                DOp::IToF(a) => (Some(Val::F(get!(a).as_i() as f64)), tv!(a) + 1),
                DOp::FToI(a) => (Some(Val::I(get!(a).as_f() as i64)), tv!(a) + 1),

                DOp::Load { arr, idx } => {
                    // STA unit only
                    debug_assert!(self.kind == UnitKind::Sta);
                    let i = get!(idx).as_i();
                    let a = &ctx.memory[arr as usize];
                    if i < 0 || i as usize >= a.len() {
                        bail!(
                            "STA load @{}[{}] out of bounds",
                            ctx.m.arrays[arr as usize].name,
                            i
                        );
                    }
                    let v = a[i as usize];
                    let barrier = self.sta_store_commit[arr as usize];
                    let port = self.sta_read_port[arr as usize];
                    let t_issue = tv!(idx).max(self.t_ctrl).max(barrier).max(port);
                    self.sta_read_port[arr as usize] =
                        t_issue + 1 + ctx.sta_rd_port_extra(t_issue);
                    let t_done = t_issue + ctx.read_lat(t_issue);
                    ctx.bump(t_done);
                    if let Some(met) = ctx.metrics.as_mut() {
                        met.on_load_issue(t_done - t_issue);
                    }
                    if let Some(tr) = ctx.trace.as_mut() {
                        tr.push("sta", "ld_issue", 0, t_issue);
                    }
                    (Some(v), t_done)
                }
                DOp::Store { arr, idx, val } => {
                    debug_assert!(self.kind == UnitKind::Sta);
                    let i = get!(idx).as_i();
                    let v = get!(val);
                    let alen = ctx.memory[arr as usize].len();
                    if i < 0 || i as usize >= alen {
                        bail!(
                            "STA store @{}[{}] out of bounds",
                            ctx.m.arrays[arr as usize].name,
                            i
                        );
                    }
                    let port = self.sta_write_port[arr as usize];
                    let t_w = tv!(idx).max(tv!(val)).max(self.t_ctrl).max(port);
                    self.sta_write_port[arr as usize] =
                        t_w + 1 + ctx.sta_wr_port_extra(t_w);
                    let t_commit = t_w + ctx.write_lat(t_w);
                    ctx.memory[arr as usize][i as usize] = v;
                    ctx.commit_log.push((0, i, v));
                    let e = &mut self.sta_store_commit[arr as usize];
                    *e = (*e).max(t_commit);
                    ctx.stores_committed += 1;
                    ctx.bump(t_commit);
                    if let Some(tr) = ctx.trace.as_mut() {
                        tr.push("sta", "st_commit", 0, t_w);
                    }
                    (None, t_commit)
                }

                DOp::Send { chan, mem, idx, is_store } => {
                    let t = tv!(idx).max(self.t_ctrl);
                    let lat = ctx.push_lat(t);
                    let e = Elem { val: get!(idx), poison: false, mem, is_store, t };
                    if !ctx.chans.try_push(chan, e, lat) {
                        if let Some(met) = ctx.metrics.as_mut() {
                            met.on_push_blocked(chan);
                        }
                        return Ok(StepOut::Blocked(Wait { chan, needs_pop: true }));
                    }
                    if let Some(met) = ctx.metrics.as_mut() {
                        let occ = ctx.chans.len_of(chan);
                        met.on_push(chan, occ, t, false);
                    }
                    ctx.bump(t);
                    if let Some(tr) = ctx.trace.as_mut() {
                        tr.push(self.name, if is_store { "send_st" } else { "send_ld" }, mem, t);
                    }
                    (None, t)
                }
                DOp::Consume { chan, mem } => {
                    // A stall-forever fault wedges the consume even though
                    // its operand has arrived (watchdog/deadlock testing).
                    if let Some(front) = ctx.chans.front(chan) {
                        if ctx.fault().is_some_and(|fi| fi.wedge_consume(front.t)) {
                            return Ok(StepOut::Blocked(Wait { chan, needs_pop: false }));
                        }
                    }
                    // Dataflow pop: stream pops are in-order and (in these
                    // slices) unconditional per iteration, so the circuit
                    // pops ahead of branch resolution — no t_ctrl term.
                    let preview =
                        if ctx.metrics.is_some() { ctx.chans.pop_preview(chan) } else { None };
                    let Some((v, _poison, _m, t)) = ctx.chans.pop(chan, 0) else {
                        return Ok(StepOut::Blocked(Wait { chan, needs_pop: false }));
                    };
                    if let Some(met) = ctx.metrics.as_mut() {
                        let occ = ctx.chans.len_of(chan);
                        // consumer wait: how long the unit idled for the
                        // element to arrive past the pop-rate chain
                        let (et, lp) = preview.unwrap_or((t, t));
                        met.on_pop(chan, occ, t, et.saturating_sub(lp + 1));
                    }
                    let t = t + ctx.fault().map_or(0, |fi| fi.chan_pop_stall(t));
                    ctx.bump(t);
                    if let Some(tr) = ctx.trace.as_mut() {
                        tr.push(self.name, "consume", mem, t);
                    }
                    (Some(v), t)
                }
                DOp::Produce { chan, mem, val } => {
                    let t = tv!(val).max(self.t_ctrl);
                    let lat = ctx.push_lat(t);
                    let e = Elem { val: get!(val), poison: false, mem, is_store: true, t };
                    if !ctx.chans.try_push(chan, e, lat) {
                        if let Some(met) = ctx.metrics.as_mut() {
                            met.on_push_blocked(chan);
                        }
                        return Ok(StepOut::Blocked(Wait { chan, needs_pop: true }));
                    }
                    if let Some(met) = ctx.metrics.as_mut() {
                        let occ = ctx.chans.len_of(chan);
                        met.on_push(chan, occ, t, false);
                    }
                    ctx.bump(t);
                    if let Some(tr) = ctx.trace.as_mut() {
                        tr.push(self.name, "produce", mem, t);
                    }
                    (None, t)
                }
                DOp::Poison { chan, mem, pred } => {
                    let fire = match pred {
                        Some(pv) => get!(pv).as_b(),
                        None => true,
                    };
                    let t = pred.map(|pv| tv!(pv)).unwrap_or(0).max(self.t_ctrl);
                    if fire {
                        let lat = ctx.push_lat(t);
                        let e = Elem { val: Val::I(0), poison: true, mem, is_store: true, t };
                        if !ctx.chans.try_push(chan, e, lat) {
                            if let Some(met) = ctx.metrics.as_mut() {
                                met.on_push_blocked(chan);
                            }
                            return Ok(StepOut::Blocked(Wait { chan, needs_pop: true }));
                        }
                        if let Some(met) = ctx.metrics.as_mut() {
                            let occ = ctx.chans.len_of(chan);
                            met.on_push(chan, occ, t, true);
                        }
                        if let Some(tr) = ctx.trace.as_mut() {
                            tr.push(self.name, "poison", mem, t);
                        }
                    }
                    ctx.bump(t);
                    (None, t)
                }
            };

            if instr.dest != NO_DEST {
                if let Some(v) = result {
                    self.env[instr.dest as usize] = Some(v);
                    self.tval[instr.dest as usize] = t_res;
                }
            }
            ctx.bump(t_res);
            self.dyn_instrs += 1;
            self.pc += 1;
        }

        // terminator
        match block.term {
            DTerm::Br(t) => {
                self.prev = Some(self.cur);
                self.cur = t;
            }
            DTerm::CondBr { cond, t, f: fb } => {
                let c = self.env[cond as usize]
                    .ok_or_else(|| anyhow!("undefined branch condition in @{}", f.name))?;
                self.t_ctrl = self.t_ctrl.max(self.tval[cond as usize]);
                self.prev = Some(self.cur);
                self.cur = if c.as_b() { t } else { fb };
            }
            DTerm::Ret => return Ok(StepOut::Done),
            DTerm::Unterminated => bail!("unterminated block in @{}", f.name),
        }
        self.entered = false;
        self.pc = 0;
        Ok(StepOut::Progress)
    }
}

// ---------------------------------------------------------------------------
// the DU
// ---------------------------------------------------------------------------

/// Release as many in-order ready values as possible from the ROB of
/// static op `mem`, delivering atomically to every registered consumer
/// channel. With functional backpressure a full target FIFO defers the
/// release (both targets must have space — partial delivery would skew
/// dual-consumed streams); the LSQ parks `mem` on `pending` and waits
/// for a pop.
fn flush_rob(lsq: &mut Lsq, mem: u32, ctx: &mut SimCtx) {
    let cu_ch = ctx.tbl.ldval_of_mem(mem);
    let agu_ch = ctx.tbl.ldval_agu_of_mem(mem);
    loop {
        let Some((rv, rt)) = lsq.robs[mem as usize].peek_ready() else { return };
        let mut blocked = false;
        if let Some(ch) = cu_ch {
            if ctx.chans.full(ch) {
                ctx.chans.wait_for_pop(ch, lsq.bit);
                blocked = true;
            }
        }
        if let Some(ch) = agu_ch {
            if ctx.chans.full(ch) {
                ctx.chans.wait_for_pop(ch, lsq.bit);
                blocked = true;
            }
        }
        if blocked {
            if !lsq.pending.contains(&mem) {
                lsq.pending.push(mem);
                if let Some(met) = ctx.metrics.as_mut() {
                    // count once per parking, not per retry
                    if let Some(ch) = cu_ch {
                        if ctx.chans.full(ch) {
                            met.on_push_blocked(ch);
                        }
                    }
                    if let Some(ch) = agu_ch {
                        if ctx.chans.full(ch) {
                            met.on_push_blocked(ch);
                        }
                    }
                }
            }
            return;
        }
        let lat = ctx.push_lat(rt);
        if let Some(ch) = cu_ch {
            ctx.chans.push(ch, Elem { val: rv, poison: false, mem, is_store: false, t: rt }, lat);
            if let Some(met) = ctx.metrics.as_mut() {
                let occ = ctx.chans.len_of(ch);
                met.on_push(ch, occ, rt, false);
            }
        }
        if let Some(ch) = agu_ch {
            ctx.chans.push(ch, Elem { val: rv, poison: false, mem, is_store: false, t: rt }, lat);
            if let Some(met) = ctx.metrics.as_mut() {
                let occ = ctx.chans.len_of(ch);
                met.on_push(ch, occ, rt, false);
            }
        }
        lsq.robs[mem as usize].release();
    }
}

/// Process as many requests as possible for one array.
///
/// The LSQ window semantics (§3.1): requests are admitted in arrival
/// order; store *values* arrive in store order on the shared `StVal`
/// stream, so only the oldest unresolved store can resolve at a time;
/// loads may bypass value-pending stores but stall on an earlier
/// unresolved store to the same address (RAW). Poisoned stores release
/// their slot without committing.
pub(super) fn du_step(lsq: &mut Lsq, ctx: &mut SimCtx) -> Result<()> {
    let arr = lsq.arr as usize;

    // retry value deliveries deferred by functional backpressure
    let pending = std::mem::take(&mut lsq.pending);
    for mem in pending {
        flush_rob(lsq, mem, ctx);
    }

    // admit everything that has arrived (fault squeezes shrink the
    // effective queue capacities, never below 1)
    while let Some(req) = ctx.chans.pop_elem(lsq.req_ch) {
        if let Some(met) = ctx.metrics.as_mut() {
            let occ = ctx.chans.len_of(lsq.req_ch);
            met.on_pop(lsq.req_ch, occ, req.t, 0);
        }
        let mut t_enter = req.t.max(lsq.t_enter_last + 1);
        if req.is_store {
            if lsq.store_slots.len() >= ctx.eff_st_q(t_enter) {
                t_enter = t_enter.max(lsq.store_slots.pop_front().unwrap());
            }
        } else if lsq.load_slots.len() >= ctx.eff_ld_q(t_enter) {
            t_enter = t_enter.max(lsq.load_slots.pop_front().unwrap());
        }
        lsq.t_enter_last = t_enter;
        ctx.per_mem[req.mem as usize].0 += 1;
        let seq = if req.is_store {
            0
        } else {
            let rob = &mut lsq.robs[req.mem as usize];
            let s = rob.next_admit;
            rob.next_admit += 1;
            s
        };
        lsq.window.push_back(WinEntry { req, t_enter, seq });
        if let Some(met) = ctx.metrics.as_mut() {
            met.on_admit(lsq.arr, req.is_store, lsq.window.len());
        }
    }

    // process the window
    loop {
        let mut acted = false;
        let mut wi = 0;
        while wi < lsq.window.len() {
            let e = lsq.window[wi].clone();
            if e.req.is_store {
                // only the OLDEST unresolved store matches the next value
                let is_oldest_store = lsq.window.iter().take(wi).all(|x| !x.req.is_store);
                if !is_oldest_store {
                    wi += 1;
                    continue;
                }
                let Some(v) = ctx.chans.front(lsq.stval_ch).copied() else {
                    wi += 1;
                    continue;
                };
                // Lemma 6.1 runtime check: the k-th store value must pair
                // with the k-th store request of this array's stream.
                if v.mem != e.req.mem {
                    bail!(
                        "store stream order violated on @{}: request m{} paired with value m{} \
                         (sequential consistency broken)",
                        ctx.m.arrays[arr].name,
                        e.req.mem,
                        v.mem
                    );
                }
                let _ = ctx.chans.pop(lsq.stval_ch, 0);
                if let Some(met) = ctx.metrics.as_mut() {
                    let occ = ctx.chans.len_of(lsq.stval_ch);
                    // stval wait = how long the paired request sat in the
                    // window for its value; the same quantity is the
                    // decoupling-slack sample (AGU lead over CU)
                    met.on_pop(lsq.stval_ch, occ, v.t, v.t.saturating_sub(e.t_enter));
                    met.on_store_pair(lsq.arr, e.req.t, v.t, lsq.window.len());
                }
                // DropPoison is the deliberately-injected recovery bug:
                // the DU "loses" the poison bit and falls through to the
                // commit path, which the differential fuzz harness must
                // flag as a memory divergence.
                let poison_dropped =
                    v.poison && ctx.fault().is_some_and(|fi| fi.drop_poison(v.t));
                if v.poison && !poison_dropped {
                    let t_resolve = e.t_enter.max(v.t);
                    lsq.store_slots.push_back(t_resolve);
                    ctx.stores_poisoned += 1;
                    ctx.per_mem[e.req.mem as usize].1 += 1;
                    ctx.bump(t_resolve);
                    if let Some(met) = ctx.metrics.as_mut() {
                        met.on_store_poison(lsq.arr, t_resolve - e.t_enter);
                    }
                    if let Some(tr) = ctx.trace.as_mut() {
                        tr.push("du", "st_poison", e.req.mem, t_resolve);
                    }
                } else {
                    let addr = e.req.val.as_i();
                    let alen = ctx.memory[arr].len();
                    if addr < 0 || addr as usize >= alen {
                        bail!(
                            "committed store @{}[{}] out of bounds (mem op m{})",
                            ctx.m.arrays[arr].name,
                            addr,
                            e.req.mem
                        );
                    }
                    let t_w = e.t_enter.max(v.t).max(lsq.write_port);
                    lsq.write_port = t_w + 1;
                    let t_commit = t_w + ctx.write_lat(t_w);
                    ctx.memory[arr][addr as usize] = v.val;
                    ctx.commit_log.push((e.req.mem, addr, v.val));
                    lsq.commit_at[addr as usize] = t_commit;
                    lsq.store_slots.push_back(t_commit);
                    ctx.stores_committed += 1;
                    ctx.bump(t_commit);
                    if let Some(met) = ctx.metrics.as_mut() {
                        met.on_store_commit(lsq.arr, t_commit - e.t_enter);
                    }
                    if let Some(tr) = ctx.trace.as_mut() {
                        tr.push("du", "st_commit", e.req.mem, t_w);
                    }
                }
                lsq.window.remove(wi);
                acted = true;
                // restart the scan: removing the store may unblock loads
                break;
            } else {
                // load: stall only on an earlier unresolved same-address
                // store (disambiguation is exact — addresses are known at
                // admission)
                let addr = e.req.val.as_i();
                let raw_blocked = lsq
                    .window
                    .iter()
                    .take(wi)
                    .any(|x| x.req.is_store && x.req.val.as_i() == addr);
                if raw_blocked {
                    wi += 1;
                    continue;
                }
                let a = &ctx.memory[arr];
                let v = a[clamp_idx(addr, a.len())];
                let raw = if addr >= 0 && (addr as usize) < lsq.commit_at.len() {
                    lsq.commit_at[addr as usize]
                } else {
                    0
                };
                let t_issue = e.t_enter.max(raw).max(lsq.read_port);
                lsq.read_port = t_issue + 1;
                let t_done = t_issue + ctx.read_lat(t_issue);
                ctx.bump(t_done);
                if let Some(met) = ctx.metrics.as_mut() {
                    met.on_load_issue(t_done - t_issue);
                    met.on_load_done(lsq.arr, t_done - e.t_enter);
                }
                if let Some(tr) = ctx.trace.as_mut() {
                    tr.push("du", "ld_issue", e.req.mem, t_issue);
                }
                lsq.load_slots.push_back(t_done);
                if lsq.load_slots.len() > ctx.eff_ld_q(t_done) {
                    lsq.load_slots.pop_front();
                }
                // deliver through the per-op reorder buffer: the consumer
                // pops values in request order even when loads bypass
                let mem = e.req.mem;
                lsq.robs[mem as usize].insert(e.seq, (v, t_done));
                flush_rob(lsq, mem, ctx);
                lsq.window.remove(wi);
                acted = true;
                break;
            }
        }
        if !acted {
            break;
        }
    }

    // park until new input arrives on either stream
    ctx.chans.wait_for_push(lsq.req_ch, lsq.bit);
    ctx.chans.wait_for_push(lsq.stval_ch, lsq.bit);
    Ok(())
}

/// Snapshot of every non-empty per-array LSQ, for stall diagnostics.
pub(super) fn lsq_stats(lsqs: &[Lsq], m: &Module) -> Vec<LsqStat> {
    lsqs.iter()
        .filter(|l| !l.window.is_empty() || !l.store_slots.is_empty() || !l.load_slots.is_empty())
        .map(|l| LsqStat {
            array: m.arrays[l.arr as usize].name.clone(),
            window: l.window.len(),
            store_slots: l.store_slots.len(),
            load_slots: l.load_slots.len(),
        })
        .collect()
}

// ---------------------------------------------------------------------------
// top level
// ---------------------------------------------------------------------------

/// Scheduler entity bits (wake-list): AGU, CU, then one per array LSQ.
pub(super) const AGU_BIT: u64 = 1 << 0;
pub(super) const CU_BIT: u64 = 1 << 1;

#[inline]
pub(super) fn lsq_bit(i: usize) -> u64 {
    1 << (2 + i)
}

/// Convert the dense per-mem stats to the public sparse map. Entry
/// creation in the old engine was admission-driven, so "requests > 0"
/// reproduces the exact key set.
pub(super) fn per_mem_map(v: &[(u64, u64)]) -> FxHashMap<u32, (u64, u64)> {
    let mut out = FxHashMap::default();
    for (i, &(req, poi)) in v.iter().enumerate() {
        if req > 0 {
            out.insert(i as u32, (req, poi));
        }
    }
    out
}

/// Simulate a compiled architecture over `args` and an initial memory
/// image.
///
/// One-shot convenience wrapper over [`SimSession`]: repeated-run
/// consumers (bench timing loops, fuzz minimization) should hold a
/// session instead, which reuses every per-run allocation and restores
/// memory by memcpy. Results are identical either way.
pub fn simulate(
    c: &Compiled,
    args: &[Val],
    memory: Memory,
    cfg: &MachineConfig,
) -> Result<SimResult> {
    let mut session = SimSession::new(c, cfg, memory)?;
    session.run(args)?;
    Ok(session.into_result())
}

/// Simulate and also return a functional cross-check against the
/// reference interpreter of the original function.
pub fn simulate_checked(
    m: &Module,
    func_idx: usize,
    c: &Compiled,
    args: &[Val],
    memory: Memory,
    cfg: &MachineConfig,
) -> Result<(SimResult, bool)> {
    let reference = super::interp::interpret(
        m,
        &m.funcs[func_idx],
        args,
        memory.clone(),
        cfg.max_dyn_instrs,
    )?;
    let sim = simulate(c, args, memory, cfg)?;
    let matches = super::memory_diff(&sim.memory, &reference.memory).is_none();
    let expected_match = !matches!(c.arch(), Arch::Oracle);
    if expected_match && !matches {
        let (ai, i) = super::memory_diff(&sim.memory, &reference.memory).unwrap();
        bail!(
            "{} final memory diverges from reference at @{}[{}]: {} vs {}",
            c.arch().name(),
            m.array(crate::ir::ArrayId(ai as u32)).name,
            i,
            sim.memory[ai][i],
            reference.memory[ai][i],
        );
    }
    Ok((sim, matches))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_module;
    use crate::sim::zero_memory;
    use crate::transform::{build, Arch};

    const FIG1C: &str = r#"
array @A : i64[64]
array @idx : i64[64]

func @fig1c(%n: i64) {
entry:
  %c0 = const.i 0
  br header
header:
  %i = phi i64 [entry: %c0], [latch: %inext]
  %cc = icmp.lt %i, %n
  condbr %cc, body, exit
body:
  %a = load @A[%i]
  %zero = const.i 0
  %p = icmp.gt %a, %zero
  condbr %p, then, latch
then:
  %w = load @idx[%i]
  %aw = load @A[%w]
  %c1 = const.i 1
  %fv = add.i %aw, %c1
  store @A[%w], %fv
  br latch
latch:
  %c1b = const.i 1
  %inext = add.i %i, %c1b
  br header
exit:
  ret
}
"#;

    fn fig1c_memory(m: &crate::ir::Module) -> Memory {
        let mut mem = zero_memory(m);
        for i in 0..64 {
            mem[0][i] = Val::I(if i % 3 == 0 { 5 } else { -5 });
            mem[1][i] = Val::I(((i * 7) % 64) as i64);
        }
        mem
    }

    #[test]
    fn sta_dae_spec_match_reference() {
        let m = parse_module(FIG1C).unwrap();
        let mem = fig1c_memory(&m);
        let cfg = MachineConfig::default();
        let mut cycles = std::collections::HashMap::new();
        for arch in [Arch::Sta, Arch::Dae, Arch::Spec] {
            let c = build(&m, 0, arch).unwrap();
            let (sim, ok) =
                simulate_checked(&m, 0, &c, &[Val::I(64)], mem.clone(), &cfg).unwrap();
            assert!(ok, "{arch:?} memory matches");
            cycles.insert(arch, sim.cycles);
            if arch == Arch::Spec {
                assert!(sim.stores_poisoned > 0, "some stores must be poisoned");
                assert!(sim.misspec_rate > 0.3 && sim.misspec_rate < 0.9);
            }
        }
        // the paper's shape: DAE (no spec) is much slower than SPEC
        assert!(
            cycles[&Arch::Dae] > 2 * cycles[&Arch::Spec],
            "DAE {} vs SPEC {}",
            cycles[&Arch::Dae],
            cycles[&Arch::Spec]
        );
        // and SPEC beats STA
        assert!(
            cycles[&Arch::Sta] > cycles[&Arch::Spec],
            "STA {} vs SPEC {}",
            cycles[&Arch::Sta],
            cycles[&Arch::Spec]
        );
    }

    #[test]
    fn oracle_runs_and_diverges_on_adversarial_data() {
        let m = parse_module(FIG1C).unwrap();
        let mem = fig1c_memory(&m);
        let cfg = MachineConfig::default();
        let c = build(&m, 0, Arch::Oracle).unwrap();
        let (sim, matches) =
            simulate_checked(&m, 0, &c, &[Val::I(64)], mem, &cfg).unwrap();
        assert!(!matches, "oracle must be functionally wrong on this input");
        assert!(sim.cycles > 0);
    }

    #[test]
    fn wedged_machine_reports_stall_diagnostic() {
        use crate::fault::{FaultInjector, FaultPlan};
        let m = parse_module(FIG1C).unwrap();
        let mem = fig1c_memory(&m);
        // Stall-forever fault: every ConsumeVal blocks, so the machine
        // must terminate via the structured deadlock path, not hang.
        let cfg = MachineConfig {
            fault: Some(FaultInjector::new(FaultPlan::wedge())),
            ..MachineConfig::default()
        };
        let c = build(&m, 0, Arch::Dae).unwrap();
        let err = simulate(&c, &[Val::I(64)], mem, &cfg).unwrap_err();
        let diag = err
            .downcast_ref::<StallDiagnostic>()
            .expect("wedge must produce a StallDiagnostic root cause");
        assert!(matches!(diag.reason, StallReason::Deadlock));
        let pending: usize = diag.channels.iter().map(|ch| ch.occupancy).sum();
        assert!(pending > 0, "diagnostic must list stuck channel elements");
        assert!(!diag.units.is_empty());
        // the rendering names the channels so a human can read the report
        let rendered = diag.render();
        assert!(rendered.contains("stall diagnostic"));
        assert!(rendered.contains("chan "), "render lists channels:\n{rendered}");
    }

    #[test]
    fn instr_budget_reports_structured_diag() {
        let m = parse_module(FIG1C).unwrap();
        let mem = fig1c_memory(&m);
        let cfg = MachineConfig { max_dyn_instrs: 16, ..MachineConfig::default() };
        let c = build(&m, 0, Arch::Sta).unwrap();
        let err = simulate(&c, &[Val::I(64)], mem, &cfg).unwrap_err();
        let diag = err
            .downcast_ref::<StallDiagnostic>()
            .expect("budget trip must produce a StallDiagnostic root cause");
        match &diag.reason {
            StallReason::InstrBudget { unit, limit } => {
                assert_eq!(unit, "sta");
                assert_eq!(*limit, 16);
            }
            other => panic!("expected InstrBudget, got {other:?}"),
        }
        assert_eq!(diag.units.len(), 1);
        assert!(diag.units[0].dyn_instrs >= 16);
    }

    #[test]
    fn chan_cap_backpressure_is_timing_neutral() {
        // Bounded channels now block the producer host-side (functional
        // backpressure), but timestamps are data-driven: shrinking the
        // cap to 1 must not change a single cycle or result bit.
        let m = parse_module(FIG1C).unwrap();
        let mem = fig1c_memory(&m);
        let deflt = MachineConfig::default();
        let tight = MachineConfig { chan_cap: 1, ..MachineConfig::default() };
        for arch in [Arch::Dae, Arch::Spec] {
            let c = build(&m, 0, arch).unwrap();
            let a = simulate(&c, &[Val::I(64)], mem.clone(), &deflt).unwrap();
            let b = simulate(&c, &[Val::I(64)], mem.clone(), &tight).unwrap();
            assert_eq!(a.cycles, b.cycles, "{arch:?}: cap must not change timing");
            assert_eq!(a.dyn_instrs, b.dyn_instrs, "{arch:?}");
            assert_eq!(a.stores_committed, b.stores_committed, "{arch:?}");
            assert_eq!(a.commit_log, b.commit_log, "{arch:?}: commit order pinned");
            assert!(crate::sim::memory_diff(&a.memory, &b.memory).is_none());
        }
    }
}
