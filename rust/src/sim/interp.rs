//! Functional reference interpreter for *monolithic* functions — the
//! golden semantics every architecture's final memory is compared
//! against (ORACLE is asserted to diverge on adversarial inputs).

use super::Memory;
use crate::ir::types::Val;
use crate::ir::{BinOp, BlockId, CmpOp, Function, Module, Op, Terminator};
use anyhow::{bail, Result};

#[derive(Debug)]
pub struct InterpResult {
    pub memory: Memory,
    /// Dynamic instruction count.
    pub dyn_instrs: u64,
    /// Dynamic executions per static memory op (`mem` id order follows
    /// layout order, matching `decouple`).
    pub mem_exec_counts: Vec<u64>,
    /// Trip counts per block.
    pub block_counts: Vec<u64>,
    /// Committed stores in program order: (mem id, address, value).
    pub store_log: Vec<(u32, i64, crate::ir::types::Val)>,
}

pub fn eval_ibin(op: BinOp, a: i64, b: i64) -> i64 {
    match op {
        BinOp::Add => a.wrapping_add(b),
        BinOp::Sub => a.wrapping_sub(b),
        BinOp::Mul => a.wrapping_mul(b),
        BinOp::Div => {
            if b == 0 {
                0
            } else {
                a.wrapping_div(b)
            }
        }
        BinOp::Rem => {
            if b == 0 {
                0
            } else {
                a.wrapping_rem(b)
            }
        }
        BinOp::And => a & b,
        BinOp::Or => a | b,
        BinOp::Xor => a ^ b,
        BinOp::Shl => a.wrapping_shl(b as u32),
        BinOp::Shr => a.wrapping_shr(b as u32),
        BinOp::Min => a.min(b),
        BinOp::Max => a.max(b),
    }
}

pub fn eval_fbin(op: BinOp, a: f64, b: f64) -> f64 {
    match op {
        BinOp::Add => a + b,
        BinOp::Sub => a - b,
        BinOp::Mul => a * b,
        BinOp::Div => a / b,
        BinOp::Rem => a % b,
        BinOp::Min => a.min(b),
        BinOp::Max => a.max(b),
        _ => f64::NAN,
    }
}

pub fn eval_icmp(op: CmpOp, a: i64, b: i64) -> bool {
    match op {
        CmpOp::Eq => a == b,
        CmpOp::Ne => a != b,
        CmpOp::Lt => a < b,
        CmpOp::Le => a <= b,
        CmpOp::Gt => a > b,
        CmpOp::Ge => a >= b,
    }
}

pub fn eval_fcmp(op: CmpOp, a: f64, b: f64) -> bool {
    match op {
        CmpOp::Eq => a == b,
        CmpOp::Ne => a != b,
        CmpOp::Lt => a < b,
        CmpOp::Le => a <= b,
        CmpOp::Gt => a > b,
        CmpOp::Ge => a >= b,
    }
}

/// Clamp an index into `[0, size)` — speculative loads may compute
/// addresses on never-taken paths; hardware discards them, we clamp
/// (documented in DESIGN.md; the *functional* result of a clamped
/// speculative load is never architecturally used).
pub fn clamp_idx(idx: i64, size: usize) -> usize {
    idx.clamp(0, size.saturating_sub(1) as i64) as usize
}

/// Interpret `f` over `args` and an initial memory image.
pub fn interpret(
    m: &Module,
    f: &Function,
    args: &[Val],
    mut memory: Memory,
    max_instrs: u64,
) -> Result<InterpResult> {
    if args.len() != f.params.len() {
        bail!("@{}: expected {} args, got {}", f.name, f.params.len(), args.len());
    }
    let mut env: Vec<Option<Val>> = vec![None; f.values.len()];
    for (i, &p) in f.params.iter().enumerate() {
        env[p.index()] = Some(args[i]);
    }

    // mem ids in layout order (must match decouple::decouple)
    let mut mem_ids: Vec<Option<u32>> = vec![None; f.instrs.len()];
    let mut n_mem = 0u32;
    for b in &f.blocks {
        for &iid in &b.instrs {
            if f.instr(iid).op.is_memory() {
                mem_ids[iid.index()] = Some(n_mem);
                n_mem += 1;
            }
        }
    }
    let mut mem_exec_counts = vec![0u64; n_mem as usize];
    let mut block_counts = vec![0u64; f.num_blocks()];
    let mut store_log: Vec<(u32, i64, Val)> = Vec::new();

    let mut cur = f.entry;
    let mut prev: Option<BlockId> = None;
    let mut dyn_instrs = 0u64;

    loop {
        block_counts[cur.index()] += 1;
        // φs evaluate atomically on entry
        let block = &f.blocks[cur.index()];
        let mut phi_updates: Vec<(usize, Val)> = Vec::new();
        for &iid in &block.instrs {
            let instr = f.instr(iid);
            if let Op::Phi { incomings, .. } = &instr.op {
                let pb = prev.expect("φ in entry block");
                let (_, v) = incomings
                    .iter()
                    .find(|(bb, _)| *bb == pb)
                    .unwrap_or_else(|| panic!("φ has no incoming for {pb} in {}", block.name));
                let val = env[v.index()].expect("φ operand undefined");
                phi_updates.push((instr.result.unwrap().index(), val));
            } else {
                break;
            }
        }
        for (vi, val) in phi_updates {
            env[vi] = Some(val);
        }

        for &iid in &block.instrs {
            let instr = f.instr(iid);
            dyn_instrs += 1;
            if dyn_instrs > max_instrs {
                bail!("@{}: exceeded {} dynamic instructions", f.name, max_instrs);
            }
            let get = |v: crate::ir::ValueId| env[v.index()].expect("use of undefined value");
            let result: Option<Val> = match &instr.op {
                Op::Phi { .. } => continue, // handled above
                Op::ConstI(x) => Some(Val::I(*x)),
                Op::ConstF(x) => Some(Val::F(*x)),
                Op::ConstB(x) => Some(Val::B(*x)),
                Op::IBin(o, a, b) => Some(Val::I(eval_ibin(*o, get(*a).as_i(), get(*b).as_i()))),
                Op::FBin(o, a, b) => Some(Val::F(eval_fbin(*o, get(*a).as_f(), get(*b).as_f()))),
                Op::ICmp(o, a, b) => Some(Val::B(eval_icmp(*o, get(*a).as_i(), get(*b).as_i()))),
                Op::FCmp(o, a, b) => Some(Val::B(eval_fcmp(*o, get(*a).as_f(), get(*b).as_f()))),
                Op::Not(a) => Some(Val::B(!get(*a).as_b())),
                Op::Select { cond, t, f: fv, .. } => {
                    Some(if get(*cond).as_b() { get(*t) } else { get(*fv) })
                }
                Op::IToF(a) => Some(Val::F(get(*a).as_i() as f64)),
                Op::FToI(a) => Some(Val::I(get(*a).as_f() as i64)),
                Op::Load { arr, idx, .. } => {
                    mem_exec_counts[mem_ids[iid.index()].unwrap() as usize] += 1;
                    let a = &memory[arr.index()];
                    let i = get(*idx).as_i();
                    if i < 0 || i as usize >= a.len() {
                        bail!(
                            "@{}: load @{}[{i}] out of bounds (size {})",
                            f.name,
                            m.array(*arr).name,
                            a.len()
                        );
                    }
                    Some(a[i as usize])
                }
                Op::Store { arr, idx, val } => {
                    let mem_id = mem_ids[iid.index()].unwrap();
                    mem_exec_counts[mem_id as usize] += 1;
                    let i = get(*idx).as_i();
                    let v = get(*val);
                    store_log.push((mem_id, i, v));
                    let a = &mut memory[arr.index()];
                    if i < 0 || i as usize >= a.len() {
                        bail!(
                            "@{}: store @{}[{i}] out of bounds (size {})",
                            f.name,
                            m.array(*arr).name,
                            a.len()
                        );
                    }
                    a[i as usize] = v;
                    None
                }
                op @ (Op::SendLdAddr { .. }
                | Op::SendStAddr { .. }
                | Op::ConsumeVal { .. }
                | Op::ProduceVal { .. }
                | Op::PoisonVal { .. }) => {
                    bail!("@{}: channel op {op:?} in monolithic interpreter", f.name)
                }
            };
            if let (Some(r), Some(v)) = (instr.result, result) {
                env[r.index()] = Some(v);
            }
        }

        match &block.term {
            Terminator::Br(t) => {
                prev = Some(cur);
                cur = *t;
            }
            Terminator::CondBr { cond, t, f: fb } => {
                let c = env[cond.index()].expect("undefined branch condition").as_b();
                prev = Some(cur);
                cur = if c { *t } else { *fb };
            }
            Terminator::Ret => {
                return Ok(InterpResult { memory, dyn_instrs, mem_exec_counts, block_counts, store_log })
            }
            Terminator::Unterminated => bail!("unterminated block in @{}", f.name),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_single;

    #[test]
    fn interprets_hist_like_loop() {
        let (m, f) = parse_single(
            r#"
array @A : i64[8]
array @idx : i64[8]

func @k(%n: i64) {
entry:
  %c0 = const.i 0
  br header
header:
  %i = phi i64 [entry: %c0], [latch: %inext]
  %cc = icmp.lt %i, %n
  condbr %cc, body, exit
body:
  %a = load @A[%i]
  %zero = const.i 0
  %p = icmp.gt %a, %zero
  condbr %p, then, latch
then:
  %w = load @idx[%i]
  %aw = load @A[%w]
  %c1 = const.i 1
  %fv = add.i %aw, %c1
  store @A[%w], %fv
  br latch
latch:
  %c1b = const.i 1
  %inext = add.i %i, %c1b
  br header
exit:
  ret
}
"#,
        )
        .unwrap();
        let mut mem = super::super::zero_memory(&m);
        // A = [1, -1, 1, -1, ...]; idx = [0, 1, 2, ...] reversed
        for i in 0..8 {
            mem[0][i] = Val::I(if i % 2 == 0 { 1 } else { -1 });
            mem[1][i] = Val::I((7 - i) as i64);
        }
        let r = interpret(&m, &f, &[Val::I(8)], mem, 1_000_000).unwrap();
        // for even i (A[i] = 1 > 0): A[7-i] += 1. i=0→A[7]+=1, i=2→A[5]+=1,
        // i=4→A[3]+=1, i=6→A[1]+=1. A[1] was -1 → 0; A[3] -1→0; etc.
        assert_eq!(r.memory[0][7], Val::I(0)); // was -1, +1
        assert_eq!(r.memory[0][5], Val::I(0));
        assert_eq!(r.memory[0][0], Val::I(1)); // untouched
        assert_eq!(r.mem_exec_counts.len(), 4);
        assert_eq!(r.mem_exec_counts[0], 8); // guard load every iter
        assert_eq!(r.mem_exec_counts[3], 4); // store on even iters
    }

    #[test]
    fn bounds_error_detected() {
        let (m, f) = parse_single(
            r#"
array @A : i64[4]
func @k() {
entry:
  %c9 = const.i 9
  %v = load @A[%c9]
  ret
}
"#,
        )
        .unwrap();
        let mem = super::super::zero_memory(&m);
        assert!(interpret(&m, &f, &[], mem, 1000).is_err());
    }
}
