//! Reusable simulation sessions: allocate the whole machine once per
//! `(Compiled, MachineConfig)` and re-run it with zero steady-state heap
//! allocation.
//!
//! A [`SimSession`] owns every per-run buffer — unit register files,
//! the dense channel vector, LSQ windows/ROBs/slot rings, per-mem stat
//! vectors, the commit log, and a retained working [`Memory`] restored
//! from an immutable [`MemorySnapshot`] by `copy_from_slice` (memcpy)
//! instead of a fresh `memory.clone()` per call. [`SimSession::run`]
//! resets all of that in place (capacity retained) and re-executes the
//! engine; results are bit-identical to a fresh
//! [`simulate`](super::machine::simulate) call, which is itself a thin
//! one-shot wrapper over this type.
//!
//! Reuse is safe because every reset restores exactly the
//! freshly-constructed state and resets happen at the *start* of `run`,
//! so even a run that returned `Err` (stall diagnostics, fault-injected
//! failures) cannot poison the next run. What a session pins at
//! construction: the compiled program (borrowed) and the machine shape
//! (channel count, array sizes). What may vary between runs: arguments
//! ([`SimSession::run`]) and the fault plan ([`SimSession::set_fault`]).
//! To vary anything else — the module, the memory image, timing
//! parameters — build a new session.

use super::decoded::{ChanTable, DecodedSim};
use super::machine::{
    chan_name, deadline_from, du_step, lsq_bit, lsq_stats, per_mem_map, Channels, Lsq, SimCtx,
    SimResult, Unit, UnitKind, AGU_BIT, CU_BIT,
};
use super::stall::StallReason;
use super::trace::Trace;
use super::{MachineConfig, Memory};
use crate::fault::FaultInjector;
use crate::ir::types::Val;
use crate::ir::Module;
use crate::metrics::{Metrics, MetricsSummary};
use crate::transform::Compiled;
use crate::util::Json;
use anyhow::{bail, Result};

/// Immutable copy of the initial memory image a session restores from
/// before every re-run (plain memcpy per array; `Val` is `Copy`).
pub struct MemorySnapshot(Memory);

impl MemorySnapshot {
    pub fn new(memory: Memory) -> Self {
        MemorySnapshot(memory)
    }

    /// Restore `mem` to the snapshot state. `mem` must have the same
    /// shape (it always does inside a session: the working buffer is a
    /// clone of the snapshot and array lengths never change).
    fn restore_into(&self, mem: &mut Memory) {
        for (dst, src) in mem.iter_mut().zip(&self.0) {
            dst.copy_from_slice(src);
        }
    }

    pub fn as_memory(&self) -> &Memory {
        &self.0
    }
}

/// Scalar statistics of one completed run — everything in [`SimResult`]
/// that is not a buffer the session retains.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RunStats {
    pub cycles: u64,
    pub dyn_instrs: u64,
    pub stores_committed: u64,
    pub stores_poisoned: u64,
    pub spec_store_reqs: u64,
    pub misspec_rate: f64,
}

/// Allocated execution engine: the unit state for the compiled shape.
enum Engine<'c> {
    Sta {
        unit: Unit<'c>,
    },
    Dae {
        agu: Unit<'c>,
        cu: Unit<'c>,
        lsqs: Vec<Lsq>,
        /// Static ids of speculatively hoisted stores (misspec stats).
        spec_mems: Vec<u32>,
        /// Static ids of speculatively hoisted loads (metrics only).
        spec_load_mems: Vec<u32>,
    },
}

/// A reusable simulation context for one `(Compiled, MachineConfig)`
/// pair. See the module docs for the allocation/reset contract.
///
/// ```text
/// let mut s = SimSession::new(&compiled, &cfg, workload.memory.clone())?;
/// for _ in 0..samples {
///     let stats = s.run(&workload.args)?;   // zero-alloc steady state
/// }
/// let result = s.into_result();             // final run as a SimResult
/// ```
pub struct SimSession<'c> {
    c: &'c Compiled,
    cfg: MachineConfig,
    snapshot: MemorySnapshot,
    memory: Memory,
    chans: Channels,
    engine: Engine<'c>,
    per_mem: Vec<(u64, u64)>,
    commit_log: Vec<(u32, i64, Val)>,
    trace: Option<Trace>,
    /// Raw telemetry collectors (when `cfg.metrics`), reset per run.
    metrics: Option<Metrics>,
    /// Folded summary of the most recent successful run.
    last_metrics: Option<MetricsSummary>,
    last: RunStats,
    ran: bool,
}

fn parts<'c>(c: &'c Compiled) -> (&'c Module, &'c DecodedSim) {
    match c {
        Compiled::Monolithic { module, decoded, .. } => (module, decoded),
        Compiled::Dae { program, decoded, .. } => (&program.module, decoded),
    }
}

impl<'c> SimSession<'c> {
    /// Allocate a session over `initial` memory. The image is kept as
    /// the restore snapshot; one working clone is made here — exactly
    /// the copy count of a single old-style `simulate` call.
    pub fn new(c: &'c Compiled, cfg: &MachineConfig, initial: Memory) -> Result<Self> {
        let (module, decoded) = parts(c);
        let n_arrays = module.arrays.len();
        let engine = match c {
            Compiled::Monolithic { .. } => Engine::Sta {
                unit: Unit::new(UnitKind::Sta, "sta", &decoded.fns[0], n_arrays),
            },
            Compiled::Dae { .. } => {
                if n_arrays > 62 {
                    bail!(
                        "wake-list scheduler supports at most 62 memory arrays (got {})",
                        n_arrays
                    );
                }
                Engine::Dae {
                    agu: Unit::new(UnitKind::Agu, "agu", &decoded.fns[0], n_arrays),
                    cu: Unit::new(UnitKind::Cu, "cu", &decoded.fns[1], n_arrays),
                    lsqs: (0..n_arrays)
                        .map(|i| {
                            // commit_at is dense over the *actual* image
                            Lsq::new(i as u32, lsq_bit(i), &decoded.chans, initial[i].len())
                        })
                        .collect(),
                    spec_mems: c.speculated_mems(),
                    spec_load_mems: c.speculated_load_mems(),
                }
            }
        };
        let memory = initial.clone();
        Ok(SimSession {
            c,
            cfg: cfg.clone(),
            snapshot: MemorySnapshot::new(initial),
            memory,
            chans: Channels::new(decoded.chans.len(), cfg.chan_cap),
            engine,
            per_mem: vec![(0, 0); decoded.chans.n_mems()],
            commit_log: Vec::new(),
            trace: None,
            metrics: cfg.metrics.then(|| Metrics::new(decoded.chans.len(), n_arrays)),
            last_metrics: None,
            last: RunStats::default(),
            ran: false,
        })
    }

    /// Swap the fault plan between runs (fuzz minimization re-runs one
    /// workload under many candidate plans). `None` runs clean.
    pub fn set_fault(&mut self, fault: Option<FaultInjector>) {
        self.cfg.fault = fault;
    }

    /// Execute one run. All machine state is reset *before* executing
    /// (memory restored by memcpy, buffers cleared in place), so a
    /// prior failed run cannot leak state into this one and the first
    /// run skips the restore entirely.
    pub fn run(&mut self, args: &[Val]) -> Result<RunStats> {
        if self.ran {
            self.snapshot.restore_into(&mut self.memory);
        }
        self.ran = true;
        self.chans.reset();
        self.per_mem.fill((0, 0));
        self.commit_log.clear();
        if self.cfg.trace {
            match &mut self.trace {
                Some(tr) => tr.events.clear(),
                None => self.trace = Some(Trace::default()),
            }
        } else {
            self.trace = None;
        }
        let (module, decoded) = parts(self.c);
        if self.cfg.metrics {
            match &mut self.metrics {
                Some(met) => met.reset(),
                None => {
                    self.metrics =
                        Some(Metrics::new(decoded.chans.len(), module.arrays.len()))
                }
            }
        } else {
            self.metrics = None;
        }
        self.last_metrics = None;
        let (stats, metrics) = run_engine(
            module,
            &decoded.chans,
            &self.cfg,
            &mut self.engine,
            args,
            &mut self.chans,
            &mut self.memory,
            &mut self.trace,
            &mut self.metrics,
            &mut self.per_mem,
            &mut self.commit_log,
        )?;
        self.last = stats;
        self.last_metrics = metrics;
        Ok(stats)
    }

    /// Scalar stats of the most recent successful run.
    pub fn last_stats(&self) -> RunStats {
        self.last
    }

    /// Final memory image of the most recent run.
    pub fn memory(&self) -> &Memory {
        &self.memory
    }

    /// Commit log of the most recent run, in per-array stream order.
    pub fn commit_log(&self) -> &[(u32, i64, Val)] {
        &self.commit_log
    }

    /// Pipeline trace of the most recent run (when `cfg.trace`).
    pub fn trace(&self) -> Option<&Trace> {
        self.trace.as_ref()
    }

    /// Raw telemetry collectors of the most recent run (when
    /// `cfg.metrics`).
    pub fn metrics(&self) -> Option<&Metrics> {
        self.metrics.as_ref()
    }

    /// Folded metrics summary of the most recent successful run.
    pub fn metrics_summary(&self) -> Option<&MetricsSummary> {
        self.last_metrics.as_ref()
    }

    /// Export the most recent run as a Chrome/Perfetto `trace_event`
    /// document (needs `cfg.trace`; counter tracks additionally need
    /// `cfg.metrics`). Open the rendered JSON at
    /// <https://ui.perfetto.dev>. Works after failed runs too — the
    /// partial trace of whatever executed is exported.
    pub fn perfetto(&self, label: &str) -> Option<Json> {
        let tr = self.trace.as_ref()?;
        let (module, decoded) = parts(self.c);
        let chan_names: Vec<String> = (0..decoded.chans.len())
            .map(|i| chan_name(module, &decoded.chans, i))
            .collect();
        let array_names: Vec<String> = module.arrays.iter().map(|a| a.name.clone()).collect();
        Some(crate::metrics::perfetto::export(
            label,
            &tr.events,
            self.metrics.as_ref(),
            &chan_names,
            &array_names,
        ))
    }

    /// Consume the session into the [`SimResult`] of its last run —
    /// moves the memory/trace/commit-log buffers out without copying.
    pub fn into_result(self) -> SimResult {
        SimResult {
            cycles: self.last.cycles,
            memory: self.memory,
            dyn_instrs: self.last.dyn_instrs,
            stores_committed: self.last.stores_committed,
            stores_poisoned: self.last.stores_poisoned,
            spec_store_reqs: self.last.spec_store_reqs,
            misspec_rate: self.last.misspec_rate,
            per_mem: per_mem_map(&self.per_mem),
            trace: self.trace,
            commit_log: self.commit_log,
            metrics: self.last_metrics,
        }
    }
}

/// One engine execution over session-owned buffers. Free function with
/// disjoint `&mut` parameters (rather than a `SimSession` method) so
/// the borrow of each buffer is independent; semantics are exactly the
/// pre-session `simulate` engine.
#[allow(clippy::too_many_arguments)]
fn run_engine(
    m: &Module,
    tbl: &ChanTable,
    cfg: &MachineConfig,
    engine: &mut Engine<'_>,
    args: &[Val],
    chans: &mut Channels,
    memory: &mut Memory,
    trace: &mut Option<Trace>,
    metrics: &mut Option<Metrics>,
    per_mem: &mut [(u64, u64)],
    commit_log: &mut Vec<(u32, i64, Val)>,
) -> Result<(RunStats, Option<MetricsSummary>)> {
    let mut ctx = SimCtx {
        m,
        tbl,
        cfg,
        chans,
        memory,
        max_t: 0,
        trace,
        metrics,
        spec_store_mems: &[],
        spec_load_mems: &[],
        stores_committed: 0,
        stores_poisoned: 0,
        per_mem,
        commit_log,
        deadline: deadline_from(cfg),
    };
    match engine {
        Engine::Sta { unit } => {
            unit.reset(args);
            unit.run(&mut ctx)?;
            if !unit.done {
                return Err(ctx
                    .stall_error(StallReason::Deadlock, vec![unit.stat()], vec![])
                    .context("STA unit blocked (channel op in monolithic build?)"));
            }
            let stats = RunStats {
                cycles: ctx.max_t,
                dyn_instrs: unit.dyn_instrs,
                stores_committed: ctx.stores_committed,
                stores_poisoned: 0,
                spec_store_reqs: 0,
                misspec_rate: 0.0,
            };
            let summary = ctx.metrics_summary(&[unit.stat()]);
            Ok((stats, summary))
        }
        Engine::Dae { agu, cu, lsqs, spec_mems, spec_load_mems } => {
            ctx.spec_store_mems = spec_mems.as_slice();
            ctx.spec_load_mems = spec_load_mems.as_slice();
            agu.reset(args);
            cu.reset(args);
            for lsq in lsqs.iter_mut() {
                lsq.reset();
            }

            let all_bits =
                AGU_BIT | CU_BIT | lsqs.iter().enumerate().fold(0, |acc, (i, _)| acc | lsq_bit(i));
            let mut runnable: u64 = all_bits;
            let mut rounds: u64 = 0;
            let mut stagnant: u64 = 0;
            let mut fingerprint: (u64, u64) = (0, 0);
            loop {
                // One scheduler round, fixed order: AGU, CU, LSQ 0..n.
                // Wakes raised for a not-yet-stepped entity run this
                // round (matching the old poll-everything cadence);
                // wakes for an already-stepped entity run next round.
                let mut cur = runnable;
                let mut next: u64 = 0;
                let mut processed: u64 = 0;

                processed |= AGU_BIT;
                if cur & AGU_BIT != 0 && !agu.done {
                    if let Some(w) = agu.run(&mut ctx)? {
                        ctx.chans.register(w, AGU_BIT);
                    }
                    let woken = ctx.chans.take_woken();
                    cur |= woken & !processed;
                    next |= woken & processed;
                }
                processed |= CU_BIT;
                if cur & CU_BIT != 0 && !cu.done {
                    if let Some(w) = cu.run(&mut ctx)? {
                        ctx.chans.register(w, CU_BIT);
                    }
                    let woken = ctx.chans.take_woken();
                    cur |= woken & !processed;
                    next |= woken & processed;
                }
                for (i, lsq) in lsqs.iter_mut().enumerate() {
                    let bit = lsq_bit(i);
                    processed |= bit;
                    if cur & bit != 0 {
                        du_step(lsq, &mut ctx)?;
                        let woken = ctx.chans.take_woken();
                        cur |= woken & !processed;
                        next |= woken & processed;
                    }
                }

                if agu.done
                    && cu.done
                    && ctx.chans.all_empty()
                    && lsqs.iter().all(|l| l.window.is_empty())
                {
                    break;
                }
                if next == 0 {
                    return Err(ctx
                        .stall_error(
                            StallReason::Deadlock,
                            vec![agu.stat(), cu.stat()],
                            lsq_stats(lsqs, ctx.m),
                        )
                        .context(format!(
                            "deadlock: agu_done={} cu_done={}",
                            agu.done, cu.done
                        )));
                }
                runnable = next;
                // Progress watchdog: scheduler rounds can report wakes
                // (queue shuffling) without any timestamp or instruction
                // count advancing; bail with a diagnostic instead of
                // spinning toward max_dyn_instrs.
                rounds += 1;
                let fp = (ctx.max_t, agu.dyn_instrs + cu.dyn_instrs);
                if fp == fingerprint {
                    stagnant += 1;
                } else {
                    fingerprint = fp;
                    stagnant = 0;
                }
                if cfg.watchdog_rounds > 0 && stagnant >= cfg.watchdog_rounds {
                    return Err(ctx.stall_error(
                        StallReason::Watchdog { rounds: cfg.watchdog_rounds },
                        vec![agu.stat(), cu.stat()],
                        lsq_stats(lsqs, ctx.m),
                    ));
                }
                if rounds & 0x3FF == 0 && ctx.over_deadline() {
                    return Err(ctx.stall_error(
                        StallReason::WallClock { ms: cfg.wall_timeout_ms },
                        vec![agu.stat(), cu.stat()],
                        lsq_stats(lsqs, ctx.m),
                    ));
                }
            }

            let spec_store_reqs: u64 = spec_mems
                .iter()
                .map(|&mm| ctx.per_mem.get(mm as usize).map(|x| x.0).unwrap_or(0))
                .sum();
            let spec_poisons: u64 = spec_mems
                .iter()
                .map(|&mm| ctx.per_mem.get(mm as usize).map(|x| x.1).unwrap_or(0))
                .sum();
            let stats = RunStats {
                cycles: ctx.max_t,
                dyn_instrs: agu.dyn_instrs + cu.dyn_instrs,
                stores_committed: ctx.stores_committed,
                stores_poisoned: ctx.stores_poisoned,
                spec_store_reqs,
                misspec_rate: if spec_store_reqs > 0 {
                    spec_poisons as f64 / spec_store_reqs as f64
                } else {
                    0.0
                },
            };
            let summary = ctx.metrics_summary(&[agu.stat(), cu.stat()]);
            Ok((stats, summary))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_module;
    use crate::sim::machine::simulate;
    use crate::sim::{memory_diff, zero_memory};
    use crate::transform::{build, Arch};

    const KERNEL: &str = r#"
array @A : i64[64]
array @idx : i64[64]

func @k(%n: i64) {
entry:
  %c0 = const.i 0
  br header
header:
  %i = phi i64 [entry: %c0], [latch: %inext]
  %cc = icmp.lt %i, %n
  condbr %cc, body, exit
body:
  %a = load @A[%i]
  %zero = const.i 0
  %p = icmp.gt %a, %zero
  condbr %p, then, latch
then:
  %w = load @idx[%i]
  %aw = load @A[%w]
  %c1 = const.i 1
  %fv = add.i %aw, %c1
  store @A[%w], %fv
  br latch
latch:
  %c1b = const.i 1
  %inext = add.i %i, %c1b
  br header
exit:
  ret
}
"#;

    fn memory(m: &crate::ir::Module) -> Memory {
        let mut mem = zero_memory(m);
        for i in 0..64 {
            mem[0][i] = Val::I(if i % 3 == 0 { 5 } else { -5 });
            mem[1][i] = Val::I(((i * 7) % 64) as i64);
        }
        mem
    }

    /// Satellite pin: a session re-run (reset + memcpy restore) is
    /// bit-identical to a fresh `simulate` — cycles, memory, commit log,
    /// per-mem stats. This is what makes moving the memory clone out of
    /// the bench timing loop a pure measurement fix, not a behaviour
    /// change.
    #[test]
    fn session_rerun_is_bit_identical_to_fresh_simulate() {
        let m = parse_module(KERNEL).unwrap();
        let mem = memory(&m);
        let cfg = MachineConfig::default();
        for arch in [Arch::Sta, Arch::Dae, Arch::Spec] {
            let c = build(&m, 0, arch).unwrap();
            let fresh = simulate(&c, &[Val::I(64)], mem.clone(), &cfg).unwrap();
            let mut s = SimSession::new(&c, &cfg, mem.clone()).unwrap();
            for rerun in 0..3 {
                let stats = s.run(&[Val::I(64)]).unwrap();
                assert_eq!(stats.cycles, fresh.cycles, "{arch:?} run {rerun}");
                assert_eq!(stats.dyn_instrs, fresh.dyn_instrs, "{arch:?} run {rerun}");
                assert_eq!(
                    stats.stores_committed, fresh.stores_committed,
                    "{arch:?} run {rerun}"
                );
                assert_eq!(
                    stats.stores_poisoned, fresh.stores_poisoned,
                    "{arch:?} run {rerun}"
                );
                assert!(
                    memory_diff(s.memory(), &fresh.memory).is_none(),
                    "{arch:?} run {rerun}: memory diverged"
                );
                assert_eq!(s.commit_log(), &fresh.commit_log[..], "{arch:?} run {rerun}");
            }
            let result = s.into_result();
            assert_eq!(result.cycles, fresh.cycles, "{arch:?}");
            assert_eq!(result.per_mem, fresh.per_mem, "{arch:?}");
            assert_eq!(result.misspec_rate, fresh.misspec_rate, "{arch:?}");
        }
    }

    /// A failed run (fault-injected deadlock mid-flight) must not poison
    /// the next run on the same session, and `set_fault` swaps plans
    /// between runs.
    #[test]
    fn failed_run_does_not_poison_next_run() {
        use crate::fault::{FaultInjector, FaultPlan};
        let m = parse_module(KERNEL).unwrap();
        let mem = memory(&m);
        let cfg = MachineConfig::default();
        let c = build(&m, 0, Arch::Spec).unwrap();
        let fresh = simulate(&c, &[Val::I(64)], mem.clone(), &cfg).unwrap();

        let mut s = SimSession::new(&c, &cfg, mem).unwrap();
        // clean run, then a wedged run that errors with machine state
        // (channels, LSQ windows, partial memory writes) left mid-flight
        s.run(&[Val::I(64)]).unwrap();
        s.set_fault(Some(FaultInjector::new(FaultPlan::wedge())));
        assert!(s.run(&[Val::I(64)]).is_err());
        // back to clean: must be bit-identical to a fresh simulate
        s.set_fault(None);
        let stats = s.run(&[Val::I(64)]).unwrap();
        assert_eq!(stats.cycles, fresh.cycles);
        assert_eq!(stats.dyn_instrs, fresh.dyn_instrs);
        assert!(memory_diff(s.memory(), &fresh.memory).is_none());
        assert_eq!(s.commit_log(), &fresh.commit_log[..]);
    }

    /// Trace buffers are reused across runs without event accumulation.
    #[test]
    fn traced_session_rerun_matches() {
        let m = parse_module(KERNEL).unwrap();
        let mem = memory(&m);
        let cfg = MachineConfig { trace: true, ..MachineConfig::default() };
        let c = build(&m, 0, Arch::Spec).unwrap();
        let fresh = simulate(&c, &[Val::I(64)], mem.clone(), &cfg).unwrap();
        let fresh_n = fresh.trace.as_ref().unwrap().events.len();
        let mut s = SimSession::new(&c, &cfg, mem).unwrap();
        for _ in 0..2 {
            s.run(&[Val::I(64)]).unwrap();
            assert_eq!(s.trace().unwrap().events.len(), fresh_n);
        }
    }
}
