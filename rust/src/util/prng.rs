//! Deterministic PRNG (SplitMix64 seeding a xoshiro256**), used by every
//! data generator and by the property-test harness. No external crates.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut st = seed;
        Rng {
            s: [
                splitmix64(&mut st),
                splitmix64(&mut st),
                splitmix64(&mut st),
                splitmix64(&mut st),
            ],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        // xoshiro256**
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        // multiply-shift; bias negligible for our n
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Uniform float in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Zipf-ish skewed index in `[0, n)` (power-law degree generator for
    /// the synthetic graph).
    pub fn zipf(&mut self, n: u64, skew: f64) -> u64 {
        let u = self.f64().max(1e-12);
        let x = (n as f64) * u.powf(skew);
        (x as u64).min(n - 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn chance_rates_roughly_hold() {
        let mut r = Rng::new(9);
        let hits = (0..10_000).filter(|_| r.chance(0.25)).count();
        assert!((2000..3000).contains(&hits), "{hits}");
    }
}
