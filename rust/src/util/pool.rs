//! Bounded panic-safe worker pool over scoped threads (rayon is
//! unavailable offline). One entry point: [`parallel_map`], a
//! deterministic work-stealing map — results come back in item order
//! regardless of which worker ran what, and a panicking item becomes an
//! `Err` slot instead of taking the process (or its worker) down.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Default worker count: the machine's available parallelism (1 if
/// unknown).
pub fn default_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Render a payload from `catch_unwind` as a human-readable message.
pub fn panic_msg(e: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Apply `f` to every item on up to `jobs` scoped worker threads.
///
/// - **Deterministic ordering:** the output slot `i` always holds the
///   result for `items[i]`; workers pull items off a shared atomic
///   counter but results are merged back by index.
/// - **Panic safety:** each call runs under `catch_unwind`, so one
///   panicking item yields `Err(message)` in its slot and the worker
///   moves on to the next item. If a worker thread dies anyway (panic
///   in the unwind path), its claimed-but-unfinished items surface as
///   `Err` rather than being silently dropped.
/// - `jobs == 1` (or a single item) degenerates to a serial in-place
///   loop on the calling thread — same code path, no thread spawn.
///
/// `f` receives `(index, &item)`. Use the index for deterministic
/// per-item seeds or labels.
pub fn parallel_map<T, R, F>(items: &[T], jobs: usize, f: F) -> Vec<Result<R, String>>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let jobs = jobs.max(1).min(items.len().max(1));
    if jobs <= 1 {
        return items
            .iter()
            .enumerate()
            .map(|(i, it)| catch_unwind(AssertUnwindSafe(|| f(i, it))).map_err(panic_msg))
            .collect();
    }

    let next = AtomicUsize::new(0);
    let mut done: Vec<Vec<(usize, Result<R, String>)>> = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(jobs);
        for _ in 0..jobs {
            let next = &next;
            let f = &f;
            handles.push(scope.spawn(move || {
                let mut local: Vec<(usize, Result<R, String>)> = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= items.len() {
                        break;
                    }
                    let r = catch_unwind(AssertUnwindSafe(|| f(i, &items[i]))).map_err(panic_msg);
                    local.push((i, r));
                }
                local
            }));
        }
        for h in handles {
            // A worker that dies outright loses only its local results;
            // the missing slots are filled below.
            if let Ok(local) = h.join() {
                done.push(local);
            }
        }
    });

    let mut out: Vec<Option<Result<R, String>>> = (0..items.len()).map(|_| None).collect();
    for (i, r) in done.into_iter().flatten() {
        out[i] = Some(r);
    }
    out.into_iter()
        .map(|slot| slot.unwrap_or_else(|| Err("worker thread died mid-item".to_string())))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordered_results_any_job_count() {
        let items: Vec<u64> = (0..100).collect();
        for jobs in [1, 2, 4, 16] {
            let out = parallel_map(&items, jobs, |i, &x| {
                assert_eq!(i as u64, x);
                x * x
            });
            assert_eq!(out.len(), 100);
            for (i, r) in out.iter().enumerate() {
                assert_eq!(*r.as_ref().unwrap(), (i * i) as u64, "jobs={jobs}");
            }
        }
    }

    #[test]
    fn panics_become_err_slots() {
        let items: Vec<u64> = (0..20).collect();
        let out = parallel_map(&items, 4, |_, &x| {
            if x % 7 == 3 {
                panic!("boom on {x}");
            }
            x + 1
        });
        for (i, r) in out.iter().enumerate() {
            if i % 7 == 3 {
                let e = r.as_ref().unwrap_err();
                assert!(e.contains("boom"), "slot {i}: {e}");
            } else {
                assert_eq!(*r.as_ref().unwrap(), i as u64 + 1);
            }
        }
    }

    #[test]
    fn empty_and_oversubscribed() {
        let out: Vec<Result<u64, String>> = parallel_map(&[], 8, |_, &x: &u64| x);
        assert!(out.is_empty());
        let out = parallel_map(&[1u64, 2], 64, |_, &x| x * 10);
        assert_eq!(out[0].as_ref().unwrap(), &10);
        assert_eq!(out[1].as_ref().unwrap(), &20);
    }
}
