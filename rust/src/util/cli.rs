//! Minimal argument parser (`--key value`, `--flag`, positionals) — the
//! vendor set has no clap.

use std::collections::HashMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse `argv` (without the program name). `known_flags` lists
    /// boolean options that take no value.
    pub fn parse(argv: &[String], known_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if known_flags.contains(&name) {
                    out.flags.push(name.to_string());
                } else if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() {
                    out.options.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Worker count for `--jobs N`: absent or `0` means "use every
    /// available core" (see [`crate::util::pool::default_jobs`]).
    pub fn get_jobs(&self) -> usize {
        match self.get("jobs").and_then(|s| s.parse::<usize>().ok()) {
            Some(0) | None => crate::util::pool::default_jobs(),
            Some(n) => n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_mixed() {
        let argv: Vec<String> =
            ["run", "--seed", "7", "--trace", "--misspec=0.4", "hist"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let a = Args::parse(&argv, &["trace"]);
        assert_eq!(a.positional, vec!["run", "hist"]);
        assert_eq!(a.get_u64("seed", 0), 7);
        assert!(a.has_flag("trace"));
        assert_eq!(a.get_f64("misspec", 0.0), 0.4);
    }
}
