//! Tiny bench harness (criterion is unavailable offline): warmup +
//! timed samples with mean / stddev / min / median, criterion-like
//! output.

use std::time::Instant;

pub struct Bench {
    pub warmup: usize,
    pub samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 2, samples: 10 }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    /// Median sample: robust against one-sided scheduler noise, the
    /// preferred regression-gate statistic (schema `dae-spec-bench/v2`).
    pub median_ns: f64,
}

impl BenchStats {
    pub fn fmt_time(ns: f64) -> String {
        if ns < 1_000.0 {
            format!("{ns:.1} ns")
        } else if ns < 1_000_000.0 {
            format!("{:.2} µs", ns / 1_000.0)
        } else if ns < 1_000_000_000.0 {
            format!("{:.2} ms", ns / 1_000_000.0)
        } else {
            format!("{:.3} s", ns / 1_000_000_000.0)
        }
    }
}

impl Bench {
    pub fn new(warmup: usize, samples: usize) -> Self {
        Bench { warmup, samples }
    }

    /// Run `f` and report timing; the closure's return value is consumed
    /// with `std::hint::black_box` to defeat dead-code elimination.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchStats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_nanos() as f64);
        }
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let var =
            times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / times.len() as f64;
        let mut sorted = times.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let median = if sorted.is_empty() {
            0.0
        } else if sorted.len() % 2 == 1 {
            sorted[sorted.len() / 2]
        } else {
            (sorted[sorted.len() / 2 - 1] + sorted[sorted.len() / 2]) / 2.0
        };
        let stats = BenchStats {
            mean_ns: mean,
            stddev_ns: var.sqrt(),
            min_ns: sorted.first().copied().unwrap_or(f64::INFINITY),
            median_ns: median,
        };
        println!(
            "{name:<44} time: [{} ± {}]  (min {}, median {})",
            BenchStats::fmt_time(stats.mean_ns),
            BenchStats::fmt_time(stats.stddev_ns),
            BenchStats::fmt_time(stats.min_ns),
            BenchStats::fmt_time(stats.median_ns),
        );
        stats
    }
}
