//! Self-contained utilities. The offline vendor set has no
//! clap/criterion/proptest/rand, so the CLI parser, bench harness,
//! property-test driver and PRNG live here.

pub mod bench;
pub mod fxhash;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prng;

pub use bench::Bench;
pub use fxhash::FxHashMap;
pub use cli::Args;
pub use json::Json;
pub use pool::{default_jobs, parallel_map};
pub use prng::Rng;
