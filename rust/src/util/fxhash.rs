//! Fast non-cryptographic hasher (the rustc-hash/FxHash algorithm) for
//! the simulator's hot maps — SipHash was ~30% of simulation time in the
//! §Perf profile. Not DoS-resistant; keys are internal ids only.

use std::hash::{BuildHasherDefault, Hasher};

#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }

    #[inline]
    fn write_i64(&mut self, n: i64) {
        self.add(n as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
/// Drop-in HashMap with the fast hasher.
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distributes_and_roundtrips() {
        let mut m: FxHashMap<u64, u64> = FxHashMap::default();
        for i in 0..10_000u64 {
            m.insert(i * 7919, i);
        }
        for i in 0..10_000u64 {
            assert_eq!(m.get(&(i * 7919)), Some(&i));
        }
    }
}
