//! Minimal dependency-free JSON value type, printer and parser.
//!
//! Just enough for the benchmark harness (`BENCH_sim.json` read/write):
//! objects keep insertion order, numbers are `f64` (printed as integers
//! when exactly representable), and parse errors carry a byte offset.

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Pretty-print with 2-space indentation and a trailing newline.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() && n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else if n.is_finite() {
                    out.push_str(&format!("{n}"));
                } else {
                    // JSON has no NaN/Inf; degrade to null rather than
                    // emit an unparseable token.
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    it.write(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
        }
    }

    /// Parse a complete JSON document (trailing whitespace allowed,
    /// trailing tokens rejected).
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing bytes at offset {pos}");
        }
        Ok(v)
    }
}

fn push_indent(out: &mut String, indent: usize) {
    for _ in 0..indent {
        out.push_str("  ");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    let Some(&c) = b.get(*pos) else {
        bail!("unexpected end of input at offset {}", *pos)
    };
    match c {
        b'n' => parse_lit(b, pos, "null", Json::Null),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b'[' => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => bail!("expected ',' or ']' at offset {}", *pos),
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    bail!("expected ':' at offset {}", *pos);
                }
                *pos += 1;
                fields.push((key, parse_value(b, pos)?));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(fields));
                    }
                    _ => bail!("expected ',' or '}}' at offset {}", *pos),
                }
            }
        }
        b'-' | b'0'..=b'9' => parse_number(b, pos),
        c => bail!("unexpected byte {:?} at offset {}", c as char, *pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        bail!("invalid literal at offset {}", *pos)
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < b.len() && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-') {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos]).expect("ascii digits");
    match s.parse::<f64>() {
        Ok(n) => Ok(Json::Num(n)),
        Err(_) => bail!("invalid number {s:?} at offset {start}"),
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    if b.get(*pos) != Some(&b'"') {
        bail!("expected string at offset {}", *pos);
    }
    *pos += 1;
    let mut out = String::new();
    loop {
        let Some(&c) = b.get(*pos) else {
            bail!("unterminated string at offset {}", *pos)
        };
        *pos += 1;
        match c {
            b'"' => return Ok(out),
            b'\\' => {
                let Some(&e) = b.get(*pos) else {
                    bail!("unterminated escape at offset {}", *pos)
                };
                *pos += 1;
                match e {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000c}'),
                    b'u' => {
                        if *pos + 4 > b.len() {
                            bail!("truncated \\u escape at offset {}", *pos);
                        }
                        let hex = std::str::from_utf8(&b[*pos..*pos + 4])
                            .ok()
                            .and_then(|h| u32::from_str_radix(h, 16).ok());
                        let Some(code) = hex else {
                            bail!("invalid \\u escape at offset {}", *pos)
                        };
                        *pos += 4;
                        // Surrogate pairs are out of scope for our own
                        // files; map lone surrogates to U+FFFD.
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    e => bail!("invalid escape '\\{}' at offset {}", e as char, *pos - 1),
                }
            }
            c if c < 0x80 => out.push(c as char),
            _ => {
                // Multi-byte UTF-8: re-decode from the byte before.
                let rest = std::str::from_utf8(&b[*pos - 1..])
                    .map_err(|_| anyhow::anyhow!("invalid utf-8 at offset {}", *pos - 1))?;
                let ch = rest.chars().next().expect("non-empty");
                out.push(ch);
                *pos += ch.len_utf8() - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips_bench_like_document() {
        let doc = Json::Obj(vec![
            ("schema".into(), Json::Str("dae-spec-bench/v1".into())),
            ("seed".into(), Json::Num(2026.0)),
            (
                "results".into(),
                Json::Arr(vec![Json::Obj(vec![
                    ("kernel".into(), Json::Str("hist".into())),
                    ("mean_ns".into(), Json::Num(1234.5)),
                    ("cycles".into(), Json::Num(987.0)),
                ])]),
            ),
            ("empty".into(), Json::Arr(vec![])),
            ("flag".into(), Json::Bool(true)),
            ("nothing".into(), Json::Null),
        ]);
        let text = doc.render();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, doc);
        assert_eq!(back.get("seed").and_then(Json::as_f64), Some(2026.0));
        let results = back.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(results[0].get("kernel").and_then(Json::as_str), Some("hist"));
    }

    #[test]
    fn escapes_and_integers() {
        let doc = Json::Obj(vec![(
            "s".into(),
            Json::Str("a\"b\\c\nd\te\u{0001}f".into()),
        )]);
        let text = doc.render();
        assert!(text.contains("\\\""), "quote escaped: {text}");
        assert!(text.contains("\\u0001"), "control escaped: {text}");
        assert_eq!(Json::parse(&text).unwrap(), doc);
        assert_eq!(Json::Num(42.0).render().trim(), "42");
        assert_eq!(Json::Num(2.5).render().trim(), "2.5");
    }

    #[test]
    fn rejects_malformed_input() {
        for bad in ["", "{", "[1,]", "{\"a\" 1}", "tru", "\"abc", "1 2"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }
}
