//! `dae-spec` CLI — leader entrypoint.
//!
//! Subcommands are registered in [`dae_spec::coordinator::cli_main`]; this
//! file stays thin so the whole surface is testable as a library.

fn main() {
    let code = dae_spec::coordinator::cli_main(std::env::args().skip(1).collect());
    std::process::exit(code);
}
