//! Natural loop detection, loop nesting, canonical-form checks and
//! reducibility.
//!
//! The paper assumes a canonical loop representation — single header,
//! single backedge from the latch — and reducible control flow (§3.2).

use super::domtree::DomTree;
use crate::ir::{BlockId, Function};

#[derive(Clone, Debug)]
pub struct Loop {
    pub header: BlockId,
    /// Source of the backedge. With canonical loops there is exactly one.
    pub latches: Vec<BlockId>,
    /// All blocks in the loop body (including header and latches).
    pub blocks: Vec<BlockId>,
    /// Parent loop index in [`LoopInfo::loops`], if nested.
    pub parent: Option<usize>,
    /// Nesting depth (outermost = 1).
    pub depth: u32,
}

impl Loop {
    pub fn contains(&self, b: BlockId) -> bool {
        self.blocks.contains(&b)
    }

    /// The canonical single latch; panics if the loop is not canonical.
    pub fn latch(&self) -> BlockId {
        assert_eq!(self.latches.len(), 1, "loop at {} is not canonical", self.header);
        self.latches[0]
    }
}

pub struct LoopInfo {
    pub loops: Vec<Loop>,
    /// Innermost loop index per block.
    innermost: Vec<Option<usize>>,
    /// Is the CFG reducible? (Every retreating edge is a backedge to a
    /// dominating header.)
    pub reducible: bool,
}

impl LoopInfo {
    pub fn new(f: &Function, dom: &DomTree) -> Self {
        let n = f.num_blocks();

        // Find backedges: a -> h where h dominates a.
        // Also detect irreducibility: retreating edges (w.r.t. DFS) that
        // are not backedges.
        let mut backedges: Vec<(BlockId, BlockId)> = Vec::new();
        let mut retreating_non_back = false;
        {
            // DFS with colors to find retreating edges.
            #[derive(Clone, Copy, PartialEq)]
            enum Color {
                White,
                Grey,
                Black,
            }
            let mut color = vec![Color::White; n];
            let mut stack: Vec<(BlockId, usize)> = vec![(f.entry, 0)];
            color[f.entry.index()] = Color::Grey;
            while let Some(&mut (b, ref mut i)) = stack.last_mut() {
                let succs = f.succs(b);
                if *i < succs.len() {
                    let s = succs[*i];
                    *i += 1;
                    match color[s.index()] {
                        Color::White => {
                            color[s.index()] = Color::Grey;
                            stack.push((s, 0));
                        }
                        Color::Grey => {
                            // retreating edge
                            if dom.dominates(s, b) {
                                backedges.push((b, s));
                            } else {
                                retreating_non_back = true;
                            }
                        }
                        Color::Black => {
                            // cross/forward edge; if it retreats to a
                            // non-dominating block that's still fine
                            // (DAG edge).
                        }
                    }
                } else {
                    color[b.index()] = Color::Black;
                    stack.pop();
                }
            }
        }

        // Group backedges by header; collect loop bodies by reverse
        // reachability from latch to header.
        let preds = f.preds();
        let mut headers: Vec<BlockId> = Vec::new();
        for &(_, h) in &backedges {
            if !headers.contains(&h) {
                headers.push(h);
            }
        }

        let mut loops: Vec<Loop> = Vec::new();
        for &h in &headers {
            let latches: Vec<BlockId> =
                backedges.iter().filter(|&&(_, hh)| hh == h).map(|&(l, _)| l).collect();
            let mut blocks = vec![h];
            let mut work: Vec<BlockId> = latches.clone();
            while let Some(b) = work.pop() {
                if blocks.contains(&b) {
                    continue;
                }
                blocks.push(b);
                for &p in &preds[b.index()] {
                    if !blocks.contains(&p) {
                        work.push(p);
                    }
                }
            }
            loops.push(Loop { header: h, latches, blocks, parent: None, depth: 1 });
        }

        // Nesting: loop A is nested in B if A's header is in B's blocks
        // and A != B. Parent = smallest enclosing loop.
        let order: Vec<usize> = {
            let mut idx: Vec<usize> = (0..loops.len()).collect();
            idx.sort_by_key(|&i| loops[i].blocks.len());
            idx
        };
        for &i in &order {
            let mut best: Option<usize> = None;
            for &j in &order {
                if i == j {
                    continue;
                }
                if loops[j].blocks.len() > loops[i].blocks.len()
                    && loops[j].blocks.contains(&loops[i].header)
                {
                    match best {
                        None => best = Some(j),
                        Some(b) if loops[j].blocks.len() < loops[b].blocks.len() => {
                            best = Some(j)
                        }
                        _ => {}
                    }
                }
            }
            loops[i].parent = best;
        }
        // depths
        for i in 0..loops.len() {
            let mut d = 1;
            let mut cur = loops[i].parent;
            while let Some(p) = cur {
                d += 1;
                cur = loops[p].parent;
            }
            loops[i].depth = d;
        }

        // innermost loop per block
        let mut innermost: Vec<Option<usize>> = vec![None; n];
        for (li, l) in loops.iter().enumerate() {
            for &b in &l.blocks {
                match innermost[b.index()] {
                    None => innermost[b.index()] = Some(li),
                    Some(cur) if loops[cur].blocks.len() > l.blocks.len() => {
                        innermost[b.index()] = Some(li)
                    }
                    _ => {}
                }
            }
        }

        LoopInfo { loops, innermost, reducible: !retreating_non_back }
    }

    /// The innermost loop containing `b`, if any.
    pub fn innermost(&self, b: BlockId) -> Option<&Loop> {
        self.innermost[b.index()].map(|i| &self.loops[i])
    }

    pub fn innermost_idx(&self, b: BlockId) -> Option<usize> {
        self.innermost[b.index()]
    }

    /// Is `h` a loop header?
    pub fn is_header(&self, h: BlockId) -> bool {
        self.loops.iter().any(|l| l.header == h)
    }

    /// Is every loop canonical (single latch)?
    pub fn all_canonical(&self) -> bool {
        self.loops.iter().all(|l| l.latches.len() == 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_single;
    use crate::ir::BlockId;

    #[test]
    fn simple_loop() {
        let (_, f) = parse_single(
            r#"
func @l(%c: b1) {
entry:
  br header
header:
  condbr %c, body, exit
body:
  br header
exit:
  ret
}
"#,
        )
        .unwrap();
        let dom = DomTree::new(&f);
        let li = LoopInfo::new(&f, &dom);
        assert!(li.reducible);
        assert_eq!(li.loops.len(), 1);
        let l = &li.loops[0];
        assert_eq!(l.header, BlockId(1));
        assert_eq!(l.latches, vec![BlockId(2)]);
        assert!(l.contains(BlockId(1)) && l.contains(BlockId(2)));
        assert!(!l.contains(BlockId(3)));
        assert!(li.all_canonical());
    }

    #[test]
    fn nested_loops() {
        let (_, f) = parse_single(
            r#"
func @n(%c: b1) {
entry:
  br h1
h1:
  condbr %c, h2, exit
h2:
  condbr %c, b2, l1
b2:
  br h2
l1:
  br h1
exit:
  ret
}
"#,
        )
        .unwrap();
        let dom = DomTree::new(&f);
        let li = LoopInfo::new(&f, &dom);
        assert_eq!(li.loops.len(), 2);
        let outer = li.loops.iter().find(|l| l.header == BlockId(1)).unwrap();
        let inner = li.loops.iter().find(|l| l.header == BlockId(2)).unwrap();
        assert_eq!(outer.depth, 1);
        assert_eq!(inner.depth, 2);
        assert!(outer.blocks.contains(&BlockId(2)));
        // innermost of b2 is the inner loop
        assert_eq!(li.innermost(BlockId(3)).unwrap().header, BlockId(2));
        assert_eq!(li.innermost(BlockId(4)).unwrap().header, BlockId(1));
    }

    #[test]
    fn irreducible_detected() {
        // entry branches into the middle of a cycle: classic irreducible
        let (_, f) = parse_single(
            r#"
func @i(%c: b1) {
entry:
  condbr %c, a, b
a:
  br b
b:
  condbr %c, a, exit
exit:
  ret
}
"#,
        )
        .unwrap();
        let dom = DomTree::new(&f);
        let li = LoopInfo::new(&f, &dom);
        assert!(!li.reducible);
    }
}
