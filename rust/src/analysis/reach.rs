//! Forward-edge reachability ("reachability ignores loop backedges",
//! Algorithm 2 line 15).
//!
//! Precomputed as bitsets over the acyclic forward subgraph: with
//! backedges removed a reducible CFG is a DAG, so one pass in post-order
//! (successors before predecessors) suffices.

use super::domtree::DomTree;
use crate::ir::{BlockId, Function};

pub struct Reachability {
    /// `bits[a]` = bitset of blocks reachable from `a` (reflexive) via
    /// forward edges only.
    bits: Vec<Vec<u64>>,
}

impl Reachability {
    /// `dom` is used to identify backedges (`a -> h` with `h` dominating
    /// `a`).
    pub fn new(f: &Function, dom: &DomTree) -> Self {
        let n = f.num_blocks();
        let words = n.div_ceil(64);
        let mut bits = vec![vec![0u64; words]; n];

        // Post-order of the forward DAG: successors are finished before
        // their predecessors, so one sweep propagates full reachability.
        let po = super::rpo::post_order_from(f, f.entry, &|from, to| dom.dominates(to, from));
        for &b in &po {
            let bi = b.index();
            bits[bi][bi / 64] |= 1 << (bi % 64);
            for s in f.succs(b) {
                if dom.dominates(s, b) {
                    continue; // backedge
                }
                let si = s.index();
                if si == bi {
                    continue;
                }
                // bits[bi] |= bits[si], avoiding simultaneous &mut borrows
                let (lo, hi) = bits.split_at_mut(bi.max(si));
                let (dst, src) = if bi < si {
                    (&mut lo[bi], &hi[0])
                } else {
                    (&mut hi[0], &lo[si])
                };
                for w in 0..words {
                    dst[w] |= src[w];
                }
            }
        }

        Reachability { bits }
    }

    /// Is `to` reachable from `from` following forward edges (reflexive)?
    pub fn reachable(&self, from: BlockId, to: BlockId) -> bool {
        let t = to.index();
        self.bits[from.index()][t / 64] & (1 << (t % 64)) != 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_single;
    use crate::ir::BlockId;

    #[test]
    fn loop_reachability_ignores_backedge() {
        let (_, f) = parse_single(
            r#"
func @l(%c: b1) {
entry:
  br header
header:
  condbr %c, body, exit
body:
  condbr %c, then, latch
then:
  br latch
latch:
  br header
exit:
  ret
}
"#,
        )
        .unwrap();
        let dom = DomTree::new(&f);
        let r = Reachability::new(&f, &dom);
        let b = |i: u32| BlockId(i);
        // forward: entry(0)->header(1)->{body(2),exit(5)}, body->{then(3),latch(4)}
        assert!(r.reachable(b(0), b(5)));
        assert!(r.reachable(b(2), b(4)));
        assert!(r.reachable(b(1), b(4)));
        // backedge latch->header ignored:
        assert!(!r.reachable(b(4), b(1)));
        assert!(!r.reachable(b(4), b(2)));
        // reflexive
        assert!(r.reachable(b(3), b(3)));
        // then cannot reach exit? then->latch->header(backedge cut), latch has no
        // other succ — so no.
        assert!(!r.reachable(b(3), b(5)));
    }
}
