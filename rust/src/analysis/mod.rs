//! CFG and dataflow analyses used by the paper's transformations:
//! (reverse) post-order, dominators, post-dominators, control dependence,
//! natural loops + reducibility, forward-edge reachability, def-use
//! chains, and the loss-of-decoupling (LoD) analysis of paper §4.

pub mod control_dep;
pub mod defuse;
pub mod domtree;
pub mod lod;
pub mod loops;
pub mod reach;
pub mod rpo;

pub use control_dep::ControlDeps;
pub use defuse::DefUse;
pub use domtree::DomTree;
pub use lod::{LodAnalysis, LodKind};
pub use loops::{Loop, LoopInfo};
pub use reach::Reachability;
pub use rpo::{post_order, reverse_post_order};
