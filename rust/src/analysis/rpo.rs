//! Post-order / reverse post-order traversals.
//!
//! Reverse post-order of a DAG is a topological order — the property
//! Algorithm 1 relies on for hoisting speculative requests (§5.1.3).

use crate::ir::{BlockId, Function};

/// Post-order over blocks reachable from `entry`, following forward
/// terminator edges. `skip_edge(from, to)` filters edges (used to ignore
/// backedges / inner-loop headers).
pub fn post_order_from(
    f: &Function,
    entry: BlockId,
    skip_edge: &dyn Fn(BlockId, BlockId) -> bool,
) -> Vec<BlockId> {
    let n = f.num_blocks();
    let mut visited = vec![false; n];
    let mut out = Vec::with_capacity(n);
    // Iterative DFS with explicit stack of (block, next-succ-index).
    let mut stack: Vec<(BlockId, usize)> = vec![(entry, 0)];
    visited[entry.index()] = true;
    while let Some(&mut (bb, ref mut i)) = stack.last_mut() {
        let succs = f.succs(bb);
        if *i < succs.len() {
            let s = succs[*i];
            *i += 1;
            if !visited[s.index()] && !skip_edge(bb, s) {
                visited[s.index()] = true;
                stack.push((s, 0));
            }
        } else {
            out.push(bb);
            stack.pop();
        }
    }
    out
}

/// Post-order over all blocks reachable from the function entry.
pub fn post_order(f: &Function) -> Vec<BlockId> {
    post_order_from(f, f.entry, &|_, _| false)
}

/// Reverse post-order from the function entry.
pub fn reverse_post_order(f: &Function) -> Vec<BlockId> {
    let mut po = post_order(f);
    po.reverse();
    po
}

/// Reverse post-order of the region reachable from `start`, skipping
/// edges for which `skip_edge` returns true (Algorithm 1's traversal:
/// skip backedges and edges entering inner-loop headers).
pub fn reverse_post_order_from(
    f: &Function,
    start: BlockId,
    skip_edge: &dyn Fn(BlockId, BlockId) -> bool,
) -> Vec<BlockId> {
    let mut po = post_order_from(f, start, skip_edge);
    po.reverse();
    po
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_single;

    fn diamond() -> crate::ir::Function {
        let (_, f) = parse_single(
            r#"
func @d(%c: b1) {
entry:
  condbr %c, left, right
left:
  br join
right:
  br join
join:
  ret
}
"#,
        )
        .unwrap();
        f
    }

    #[test]
    fn rpo_of_diamond_is_topological() {
        let f = diamond();
        let rpo = reverse_post_order(&f);
        assert_eq!(rpo.len(), 4);
        assert_eq!(rpo[0].0, 0, "entry first");
        assert_eq!(rpo[3].0, 3, "join last");
        let pos = |b: u32| rpo.iter().position(|x| x.0 == b).unwrap();
        assert!(pos(0) < pos(1) && pos(0) < pos(2));
        assert!(pos(1) < pos(3) && pos(2) < pos(3));
    }

    #[test]
    fn skip_edges_prunes_region() {
        let f = diamond();
        // skip entry->left: region misses `left`
        let rpo = reverse_post_order_from(&f, crate::ir::BlockId(0), &|from, to| {
            from.0 == 0 && to.0 == 1
        });
        assert!(!rpo.iter().any(|b| b.0 == 1));
        assert!(rpo.iter().any(|b| b.0 == 3));
    }
}
