//! Dominator tree via the Cooper–Harvey–Kennedy iterative algorithm.

use super::rpo;
use crate::ir::{BlockId, Function};

pub struct DomTree {
    /// Immediate dominator per block; `idom[entry] == entry`;
    /// `None` for unreachable blocks.
    idom: Vec<Option<BlockId>>,
    entry: BlockId,
}

impl DomTree {
    pub fn new(f: &Function) -> Self {
        Self::new_from(f, f.entry, f.preds())
    }

    /// Build over the subgraph reachable from `entry` with the given
    /// predecessor lists (lets post-dominators reuse this on the reversed
    /// CFG).
    pub fn new_from(f: &Function, entry: BlockId, preds: Vec<Vec<BlockId>>) -> Self {
        let n = f.num_blocks();
        let rpo = rpo::reverse_post_order_from(f, entry, &|_, _| false);
        let mut rpo_pos = vec![usize::MAX; n];
        for (i, &b) in rpo.iter().enumerate() {
            rpo_pos[b.index()] = i;
        }

        let mut idom: Vec<Option<BlockId>> = vec![None; n];
        idom[entry.index()] = Some(entry);

        let mut changed = true;
        while changed {
            changed = false;
            for &b in rpo.iter().skip(1) {
                // first processed predecessor
                let mut new_idom: Option<BlockId> = None;
                for &p in &preds[b.index()] {
                    if idom[p.index()].is_none() {
                        continue;
                    }
                    new_idom = Some(match new_idom {
                        None => p,
                        Some(cur) => Self::intersect(&idom, &rpo_pos, p, cur),
                    });
                }
                if let Some(ni) = new_idom {
                    if idom[b.index()] != Some(ni) {
                        idom[b.index()] = Some(ni);
                        changed = true;
                    }
                }
            }
        }

        let _ = rpo_pos; // construction-only
        DomTree { idom, entry }
    }

    fn intersect(
        idom: &[Option<BlockId>],
        rpo_pos: &[usize],
        mut a: BlockId,
        mut b: BlockId,
    ) -> BlockId {
        while a != b {
            while rpo_pos[a.index()] > rpo_pos[b.index()] {
                a = idom[a.index()].unwrap();
            }
            while rpo_pos[b.index()] > rpo_pos[a.index()] {
                b = idom[b.index()].unwrap();
            }
        }
        a
    }

    pub fn entry(&self) -> BlockId {
        self.entry
    }

    pub fn is_reachable(&self, b: BlockId) -> bool {
        self.idom[b.index()].is_some()
    }

    /// Immediate dominator (None for the entry and unreachable blocks).
    pub fn idom(&self, b: BlockId) -> Option<BlockId> {
        if b == self.entry {
            None
        } else {
            self.idom[b.index()]
        }
    }

    /// Does `a` dominate `b` (reflexive)?
    pub fn dominates(&self, a: BlockId, b: BlockId) -> bool {
        if !self.is_reachable(a) || !self.is_reachable(b) {
            return false;
        }
        let mut cur = b;
        loop {
            if cur == a {
                return true;
            }
            if cur == self.entry {
                return false;
            }
            cur = self.idom[cur.index()].unwrap();
        }
    }

    pub fn strictly_dominates(&self, a: BlockId, b: BlockId) -> bool {
        a != b && self.dominates(a, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_single;
    use crate::ir::BlockId;

    /// Naive O(n²) dominator computation for cross-checking.
    fn naive_dominators(f: &crate::ir::Function) -> Vec<Vec<bool>> {
        let n = f.num_blocks();
        // dom[b] = set of blocks that dominate b
        let reachable = |without: Option<BlockId>| -> Vec<bool> {
            let mut seen = vec![false; n];
            let mut stack = vec![f.entry];
            if Some(f.entry) != without {
                seen[f.entry.index()] = true;
                while let Some(b) = stack.pop() {
                    for s in f.succs(b) {
                        if Some(s) != without && !seen[s.index()] {
                            seen[s.index()] = true;
                            stack.push(s);
                        }
                    }
                }
            }
            seen
        };
        let base = reachable(None);
        let mut dom = vec![vec![false; n]; n];
        for a in 0..n {
            let without_a = reachable(Some(BlockId(a as u32)));
            for b in 0..n {
                if base[b] && (a == b || !without_a[b]) {
                    dom[b][a] = true; // a dominates b
                }
            }
        }
        dom
    }

    #[test]
    fn matches_naive_on_nested_cfg() {
        let (_, f) = parse_single(
            r#"
func @g(%c: b1) {
entry:
  condbr %c, a, b
a:
  condbr %c, a1, a2
a1:
  br join_a
a2:
  br join_a
join_a:
  br join
b:
  br join
join:
  condbr %c, entry2, exit
entry2:
  br join
exit:
  ret
}
"#,
        )
        .unwrap();
        let dt = DomTree::new(&f);
        let naive = naive_dominators(&f);
        let n = f.num_blocks();
        for a in 0..n {
            for b in 0..n {
                let (ab, bb) = (BlockId(a as u32), BlockId(b as u32));
                if dt.is_reachable(ab) && dt.is_reachable(bb) {
                    assert_eq!(
                        dt.dominates(ab, bb),
                        naive[b][a],
                        "dominates({a},{b}) mismatch"
                    );
                }
            }
        }
    }

    #[test]
    fn idom_chain_in_loop() {
        let (_, f) = parse_single(
            r#"
func @l(%c: b1) {
entry:
  br header
header:
  condbr %c, body, exit
body:
  condbr %c, then, latch
then:
  br latch
latch:
  br header
exit:
  ret
}
"#,
        )
        .unwrap();
        let dt = DomTree::new(&f);
        // header idom = entry; body idom = header; latch idom = body
        assert_eq!(dt.idom(BlockId(1)), Some(BlockId(0)));
        assert_eq!(dt.idom(BlockId(2)), Some(BlockId(1)));
        assert_eq!(dt.idom(BlockId(4)), Some(BlockId(2)));
        assert!(dt.dominates(BlockId(1), BlockId(5)));
    }
}
