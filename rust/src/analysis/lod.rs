//! Loss-of-decoupling (LoD) analysis — paper §4.
//!
//! Given the set `A` of *non-trivially-prefetchable* loads (loads with
//! potential RAW hazards, i.e. loads from arrays that are also stored)
//! and the set `G` of address-generating instructions, the analysis
//! reports:
//!
//! - **Data LoD** (Definition 4.1): a def-use path from some `a ∈ A` to
//!   `g ∈ G`, tracing through φ incoming-block terminators. Such requests
//!   cannot be recovered by control speculation (e.g. `A[f(A[i])]`,
//!   `if (A[i]) A[i++] = 1`).
//! - **Control LoD** (Definition 4.2): a request control-dependent on a
//!   branch whose condition depends on some `a ∈ A`. The branch's block is
//!   the *LoD control dependency source*; these are what Algorithm 1
//!   speculates around.
//! - The **chain heads** (§5.1.2): source blocks that are not themselves
//!   destinations of another LoD control dependency.

use super::control_dep::ControlDeps;
use super::defuse::DefUse;
use super::domtree::DomTree;
use super::loops::LoopInfo;
use crate::ir::{ArrayId, BlockId, Function, InstrId, Module, Op};
use std::collections::{HashMap, HashSet};

/// Why a given memory op loses decoupling.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum LodKind {
    /// Def-use path from a hazardous load into the address computation.
    Data,
    /// Control-dependent on a branch fed by a hazardous load.
    Control { sources: Vec<BlockId> },
}

pub struct LodAnalysis {
    /// Arrays with potential RAW hazards (stored somewhere in the
    /// function). Loads from these form the paper's `A` set.
    pub hazard_arrays: Vec<ArrayId>,
    /// Memory ops (instr ids of `Load`/`Store`) that have a *data* LoD —
    /// speculation cannot help these (paper §4).
    pub data_lod: Vec<InstrId>,
    /// For each memory op with a control LoD: its source blocks.
    pub control_lod: HashMap<InstrId, Vec<BlockId>>,
    /// All LoD control-dependency source blocks.
    pub src_blocks: Vec<BlockId>,
    /// §5.1.2 chain heads: src blocks not themselves control-dependent on
    /// another src block (within the same innermost loop).
    pub chain_heads: Vec<BlockId>,
}

impl LodAnalysis {
    pub fn new(m: &Module, f: &Function) -> Self {
        let dom = DomTree::new(f);
        let loops = LoopInfo::new(f, &dom);
        let cd = ControlDeps::new(f);
        let du = DefUse::new(f);
        Self::with_analyses(m, f, &dom, &loops, &cd, &du)
    }

    pub fn with_analyses(
        _m: &Module,
        f: &Function,
        _dom: &DomTree,
        loops: &LoopInfo,
        cd: &ControlDeps,
        du: &DefUse,
    ) -> Self {
        // A-set arrays: stored anywhere in f ⇒ loads from them carry a RAW
        // hazard (the DU must see every earlier store address before the
        // load can issue).
        let mut hazard_arrays: Vec<ArrayId> = Vec::new();
        for instr in &f.instrs {
            if let Op::Store { arr, .. } = instr.op {
                if !hazard_arrays.contains(&arr) {
                    hazard_arrays.push(arr);
                }
            }
        }

        // Hazardous load result values (the `A` set).
        let mut hazard_load_results: HashSet<crate::ir::ValueId> = HashSet::new();
        let mut hazard_load_instrs: HashSet<InstrId> = HashSet::new();
        for (bi, b) in f.blocks.iter().enumerate() {
            let _ = bi;
            for &iid in &b.instrs {
                if let Op::Load { arr, .. } = f.instr(iid).op {
                    if hazard_arrays.contains(&arr) {
                        hazard_load_instrs.insert(iid);
                        if let Some(r) = f.instr(iid).result {
                            hazard_load_results.insert(r);
                        }
                    }
                }
            }
        }

        let block_of: HashMap<InstrId, BlockId> = {
            let mut map = HashMap::new();
            for (bi, b) in f.blocks.iter().enumerate() {
                for &iid in &b.instrs {
                    map.insert(iid, BlockId(bi as u32));
                }
            }
            map
        };

        // -- Definition 4.1: data LoD ---------------------------------------
        let mut data_lod: Vec<InstrId> = Vec::new();
        for (bi, b) in f.blocks.iter().enumerate() {
            let _ = bi;
            for &iid in &b.instrs {
                let idx = match f.instr(iid).op {
                    Op::Load { idx, .. } => idx,
                    Op::Store { idx, .. } => idx,
                    _ => continue,
                };
                let slice = du.backward_slice(f, &[idx], true);
                if slice.iter().any(|s| hazard_load_instrs.contains(s)) {
                    data_lod.push(iid);
                }
            }
        }

        // -- Definition 4.2: control LoD --------------------------------------
        // A branch block is an *LoD source* if its condition's backward
        // slice (with φ-terminator tracing) contains a hazardous load.
        let mut lod_branch: Vec<bool> = vec![false; f.num_blocks()];
        for (bi, b) in f.blocks.iter().enumerate() {
            if let crate::ir::Terminator::CondBr { cond, .. } = b.term {
                let slice = du.backward_slice(f, &[cond], true);
                if slice.iter().any(|s| hazard_load_instrs.contains(s)) {
                    lod_branch[bi] = true;
                }
            }
        }

        let mut control_lod: HashMap<InstrId, Vec<BlockId>> = HashMap::new();
        let mut src_blocks: Vec<BlockId> = Vec::new();
        for (bi, b) in f.blocks.iter().enumerate() {
            let bb = BlockId(bi as u32);
            for &iid in &b.instrs {
                if !f.instr(iid).op.is_memory() {
                    continue;
                }
                let sources: Vec<BlockId> = cd
                    .transitive(bb)
                    .into_iter()
                    .filter(|s| lod_branch[s.index()])
                    .collect();
                if !sources.is_empty() {
                    for &s in &sources {
                        if !src_blocks.contains(&s) {
                            src_blocks.push(s);
                        }
                    }
                    control_lod.insert(iid, sources);
                }
            }
        }
        src_blocks.sort();

        // -- §5.1.2 chain heads -------------------------------------------------
        // A source that is itself (transitively) control-dependent on
        // another LoD source is a chain link, not a head. Restrict to
        // sources within the same innermost loop (Algorithm 1 never leaves
        // the innermost loop of srcBB).
        let chain_heads: Vec<BlockId> = src_blocks
            .iter()
            .copied()
            .filter(|&s| {
                !cd.transitive(s).iter().any(|&other| {
                    other != s
                        && src_blocks.contains(&other)
                        && loops.innermost_idx(other) == loops.innermost_idx(s)
                })
            })
            .collect();

        let _ = block_of;
        LodAnalysis { hazard_arrays, data_lod, control_lod, src_blocks, chain_heads }
    }

    /// Does this function have any LoD at all?
    pub fn has_lod(&self) -> bool {
        !self.data_lod.is_empty() || !self.control_lod.is_empty()
    }

    /// Memory ops with a control LoD but no data LoD — the ones Algorithm 1
    /// can speculate.
    pub fn speculable_ops(&self) -> Vec<InstrId> {
        self.control_lod
            .keys()
            .copied()
            .filter(|i| !self.data_lod.contains(i))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_single;

    /// The paper's Figure 1b shape: `if (A[i] > 0) A[idx[i]] = f(...)`.
    const FIG1B: &str = r#"
array @A : i64[100]
array @idx : i64[100]

func @fig1b(%n: i64) {
entry:
  %c0 = const.i 0
  br header
header:
  %i = phi i64 [entry: %c0], [latch: %inext]
  %cc = icmp.lt %i, %n
  condbr %cc, body, exit
body:
  %a = load @A[%i]
  %zero = const.i 0
  %p = icmp.gt %a, %zero
  condbr %p, then, latch
then:
  %w = load @idx[%i]
  %aw = load @A[%w]
  %c1 = const.i 1
  %f = add.i %aw, %c1
  store @A[%w], %f
  br latch
latch:
  %c1b = const.i 1
  %inext = add.i %i, %c1b
  br header
exit:
  ret
}
"#;

    #[test]
    fn fig1b_has_control_lod_on_store() {
        let (m, f) = parse_single(FIG1B).unwrap();
        let lod = LodAnalysis::new(&m, &f);
        // A is stored → hazard array; idx is not.
        assert_eq!(lod.hazard_arrays.len(), 1);
        assert_eq!(m.array(lod.hazard_arrays[0]).name, "A");
        // no data LoD: idx[i] and A[w] addresses come from i / idx[i], and
        // idx is not hazardous.
        assert!(lod.data_lod.is_empty(), "{:?}", lod.data_lod);
        // the store (and the loads inside `then`) are control dependent on
        // `body`'s branch, which reads A → control LoD with source=body.
        assert!(!lod.control_lod.is_empty());
        let body = BlockId(2);
        assert_eq!(lod.src_blocks, vec![body]);
        assert_eq!(lod.chain_heads, vec![body]);
        for sources in lod.control_lod.values() {
            assert_eq!(sources, &vec![body]);
        }
    }

    #[test]
    fn dynamic_queue_pattern_is_data_lod() {
        // if (A[i]) A[q++] = 1 — the φ for q depends on loading from A via
        // the terminator of its incoming block (Definition 4.1 tracing).
        let (m, f) = parse_single(
            r#"
array @A : i64[100]

func @dynq(%n: i64) {
entry:
  %c0 = const.i 0
  br header
header:
  %i = phi i64 [entry: %c0], [latch: %inext]
  %q = phi i64 [entry: %c0], [latch: %qnext]
  %cc = icmp.lt %i, %n
  condbr %cc, body, exit
body:
  %a = load @A[%i]
  %zero = const.i 0
  %p = icmp.gt %a, %zero
  condbr %p, then, latch
then:
  %c1 = const.i 1
  store @A[%q], %c1
  %qinc = add.i %q, %c1
  br latch
latch:
  %qnext = phi i64 [body: %q], [then: %qinc]
  %c1b = const.i 1
  %inext = add.i %i, %c1b
  br header
exit:
  ret
}
"#,
        )
        .unwrap();
        let lod = LodAnalysis::new(&m, &f);
        // the store's address %q is a φ whose incoming block (latch) has a
        // plain br; but qnext's φ incoming block `body` terminates on %p
        // which loads A — the φ-terminator trace must catch it.
        assert!(
            !lod.data_lod.is_empty(),
            "dynamic queue store must be flagged as data LoD"
        );
    }

    #[test]
    fn no_store_no_hazard() {
        let (m, f) = parse_single(
            r#"
array @A : i64[100]
array @B : i64[100]

func @readonly(%n: i64) {
entry:
  %c0 = const.i 0
  %a = load @A[%c0]
  %b = load @B[%a]
  %p = icmp.gt %b, %c0
  condbr %p, t, e
t:
  br e
e:
  ret
}
"#,
        )
        .unwrap();
        let lod = LodAnalysis::new(&m, &f);
        assert!(lod.hazard_arrays.is_empty());
        assert!(!lod.has_lod());
    }
}
