//! Def-use chains over the SSA value arena.

use crate::ir::{Function, InstrId, Op, Terminator, ValueDef, ValueId};

pub struct DefUse {
    /// `users[v]` = instructions that use value `v` as an operand.
    users: Vec<Vec<InstrId>>,
    /// Blocks whose terminator condition uses `v`.
    term_users: Vec<Vec<crate::ir::BlockId>>,
}

impl DefUse {
    pub fn new(f: &Function) -> Self {
        let nv = f.values.len();
        let mut users = vec![Vec::new(); nv];
        let mut term_users = vec![Vec::new(); nv];
        for (bi, b) in f.blocks.iter().enumerate() {
            for &iid in &b.instrs {
                for v in f.instr(iid).op.uses() {
                    users[v.index()].push(iid);
                }
            }
            if let Terminator::CondBr { cond, .. } = b.term {
                term_users[cond.index()].push(crate::ir::BlockId(bi as u32));
            }
        }
        DefUse { users, term_users }
    }

    pub fn users(&self, v: ValueId) -> &[InstrId] {
        &self.users[v.index()]
    }

    pub fn term_users(&self, v: ValueId) -> &[crate::ir::BlockId] {
        &self.term_users[v.index()]
    }

    /// Transitive forward slice: all instructions reachable in the def-use
    /// graph starting from `roots` (values). φ nodes are traversed like
    /// any other user.
    pub fn forward_slice(&self, f: &Function, roots: &[ValueId]) -> Vec<InstrId> {
        let mut out: Vec<InstrId> = Vec::new();
        let mut seen = vec![false; f.instrs.len()];
        let mut work: Vec<ValueId> = roots.to_vec();
        let mut seen_v = vec![false; f.values.len()];
        while let Some(v) = work.pop() {
            if seen_v[v.index()] {
                continue;
            }
            seen_v[v.index()] = true;
            for &iid in self.users(v) {
                if !seen[iid.index()] {
                    seen[iid.index()] = true;
                    out.push(iid);
                    if let Some(r) = f.instr(iid).result {
                        work.push(r);
                    }
                }
            }
        }
        out
    }

    /// Backward slice: instructions that (transitively) feed the given
    /// values. Returns instruction ids; parameters terminate chains.
    /// When `trace_phi_terminators` is set, encountering a φ also pulls in
    /// the terminator conditions of the φ's incoming blocks — the paper's
    /// Definition 4.1 refinement.
    pub fn backward_slice(
        &self,
        f: &Function,
        roots: &[ValueId],
        trace_phi_terminators: bool,
    ) -> Vec<InstrId> {
        let mut out: Vec<InstrId> = Vec::new();
        let mut seen_i = vec![false; f.instrs.len()];
        let mut work: Vec<ValueId> = roots.to_vec();
        let mut seen_v = vec![false; f.values.len()];
        while let Some(v) = work.pop() {
            if seen_v[v.index()] {
                continue;
            }
            seen_v[v.index()] = true;
            let ValueDef::Instr(iid) = f.value(v).def else { continue };
            if seen_i[iid.index()] {
                continue;
            }
            seen_i[iid.index()] = true;
            out.push(iid);
            let op = &f.instr(iid).op;
            for u in op.uses() {
                work.push(u);
            }
            if trace_phi_terminators {
                if let Op::Phi { incomings, .. } = op {
                    for (bb, _) in incomings {
                        if let Terminator::CondBr { cond, .. } = f.block(*bb).term {
                            work.push(cond);
                        }
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_single;

    #[test]
    fn users_and_slices() {
        let (_, f) = parse_single(
            r#"
array @A : i64[8]
func @f(%n: i64) {
entry:
  %c1 = const.i 1
  %x = add.i %n, %c1
  %y = add.i %x, %c1
  %z = mul.i %y, %y
  store @A[%z], %x
  ret
}
"#,
        )
        .unwrap();
        let du = DefUse::new(&f);
        // find value ids by name
        let byname = |n: &str| {
            f.values
                .iter()
                .enumerate()
                .find(|(_, v)| v.name.as_deref() == Some(n))
                .map(|(i, _)| crate::ir::ValueId(i as u32))
                .unwrap()
        };
        let x = byname("x");
        let z = byname("z");
        assert_eq!(du.users(x).len(), 2); // y's add + the store
        // forward slice from x reaches y, z, store
        let fs = du.forward_slice(&f, &[x]);
        assert_eq!(fs.len(), 3);
        // backward slice from z: z, y, x, c1 (+ n is a param, stops)
        let bs = du.backward_slice(&f, &[z], false);
        assert_eq!(bs.len(), 4);
    }
}
