//! Post-dominators and control dependence.
//!
//! Control dependence follows Ferrante/Ottenstein/Warren: block `b` is
//! control dependent on branch block `a` iff `b` post-dominates some
//! successor of `a` but does not strictly post-dominate `a`. The paper
//! computes control dependencies "using the control-flow graph and
//! dominator tree" (§3.2); we use the standard post-dominance formulation.

use crate::ir::{BlockId, Function};

/// Post-dominator tree over the reversed CFG with a virtual exit that
/// connects every `Ret` block (and, defensively, every block with no
/// successors).
pub struct PostDomTree {
    /// Immediate post-dominator per block; virtual exit = `u32::MAX`.
    ipdom: Vec<Option<u32>>,
    n: usize,
}

const VEXIT: u32 = u32::MAX;

impl PostDomTree {
    pub fn new(f: &Function) -> Self {
        let n = f.num_blocks();
        // Reversed graph: node ids 0..n plus virtual exit VEXIT.
        // succs_rev(b) = preds(b) in original; entry of the reversed graph
        // is VEXIT with succs = exit blocks.
        let preds = f.preds();
        let exits: Vec<BlockId> = (0..n)
            .map(|i| BlockId(i as u32))
            .filter(|&b| f.succs(b).is_empty())
            .collect();

        // Reverse post-order on the reversed graph from VEXIT.
        let mut visited = vec![false; n];
        let mut po: Vec<u32> = Vec::with_capacity(n + 1);
        // DFS from each exit (VEXIT's successors).
        #[allow(clippy::needless_range_loop)]
        {
            let mut stack: Vec<(u32, usize)> = Vec::new();
            for &e in &exits {
                if visited[e.index()] {
                    continue;
                }
                visited[e.index()] = true;
                stack.push((e.0, 0));
                while let Some(&mut (b, ref mut i)) = stack.last_mut() {
                    let ss = &preds[b as usize];
                    if *i < ss.len() {
                        let s = ss[*i];
                        *i += 1;
                        if !visited[s.index()] {
                            visited[s.index()] = true;
                            stack.push((s.0, 0));
                        }
                    } else {
                        po.push(b);
                        stack.pop();
                    }
                }
            }
        }
        po.push(VEXIT);
        let rpo: Vec<u32> = po.iter().rev().copied().collect();
        let mut rpo_pos = vec![usize::MAX; n];
        let mut vexit_pos = 0usize;
        for (i, &b) in rpo.iter().enumerate() {
            if b == VEXIT {
                vexit_pos = i;
            } else {
                rpo_pos[b as usize] = i;
            }
        }

        let pos = |b: u32| -> usize {
            if b == VEXIT {
                vexit_pos
            } else {
                rpo_pos[b as usize]
            }
        };

        let mut ipdom: Vec<Option<u32>> = vec![None; n];
        // preds in the reversed graph = succs in original, plus VEXIT for
        // exit blocks.
        let rev_preds = |b: u32| -> Vec<u32> {
            let mut v: Vec<u32> = f.succs(BlockId(b)).iter().map(|s| s.0).collect();
            if v.is_empty() {
                v.push(VEXIT);
            }
            v
        };
        let get_idom = |ipdom: &Vec<Option<u32>>, b: u32| -> Option<u32> {
            if b == VEXIT {
                Some(VEXIT)
            } else {
                ipdom[b as usize]
            }
        };

        let mut changed = true;
        while changed {
            changed = false;
            for &b in rpo.iter() {
                if b == VEXIT {
                    continue;
                }
                let mut new_idom: Option<u32> = None;
                for p in rev_preds(b) {
                    if get_idom(&ipdom, p).is_none() {
                        continue;
                    }
                    new_idom = Some(match new_idom {
                        None => p,
                        Some(cur) => {
                            // intersect
                            let (mut a, mut c) = (p, cur);
                            while a != c {
                                while pos(a) > pos(c) {
                                    a = get_idom(&ipdom, a).unwrap();
                                }
                                while pos(c) > pos(a) {
                                    c = get_idom(&ipdom, c).unwrap();
                                }
                            }
                            a
                        }
                    });
                }
                if let Some(ni) = new_idom {
                    if ipdom[b as usize] != Some(ni) {
                        ipdom[b as usize] = Some(ni);
                        changed = true;
                    }
                }
            }
        }

        PostDomTree { ipdom, n }
    }

    /// Does `a` post-dominate `b` (reflexive)?
    pub fn post_dominates(&self, a: BlockId, b: BlockId) -> bool {
        let mut cur = b.0;
        loop {
            if cur == a.0 {
                return true;
            }
            match if cur == VEXIT { None } else { self.ipdom[cur as usize] } {
                Some(next) if next != cur => {
                    if next == VEXIT && a.0 != VEXIT {
                        return false;
                    }
                    cur = next;
                }
                _ => return false,
            }
        }
    }

    pub fn num_blocks(&self) -> usize {
        self.n
    }
}

/// Control-dependence relation, computed once per function.
pub struct ControlDeps {
    /// `deps[b]` = branch blocks that `b` is *directly* control dependent
    /// on.
    deps: Vec<Vec<BlockId>>,
}

impl ControlDeps {
    pub fn new(f: &Function) -> Self {
        let pdt = PostDomTree::new(f);
        let n = f.num_blocks();
        let mut deps = vec![Vec::new(); n];
        // Classic Ferrante/Ottenstein/Warren runner walk: for each branch
        // block `a` with successor `s`, every block on the post-dominator
        // spine from `s` up to (excluding) ipdom(a) is control dependent
        // on `a`.
        for a in 0..n {
            let ab = BlockId(a as u32);
            let succs = f.succs(ab);
            if succs.len() < 2 {
                continue;
            }
            let ipdom_a = pdt.ipdom[a]; // may be VEXIT
            for &s in &succs {
                let mut runner = s.0;
                loop {
                    if Some(runner) == ipdom_a || runner == VEXIT {
                        break;
                    }
                    if !deps[runner as usize].contains(&ab) {
                        deps[runner as usize].push(ab);
                    }
                    match pdt.ipdom[runner as usize] {
                        Some(next) => runner = next,
                        None => break,
                    }
                }
            }
        }
        ControlDeps { deps }
    }

    /// Blocks that `b` is directly control dependent on.
    pub fn direct(&self, b: BlockId) -> &[BlockId] {
        &self.deps[b.index()]
    }

    /// Transitive control dependencies of `b` (includes direct).
    pub fn transitive(&self, b: BlockId) -> Vec<BlockId> {
        let mut out: Vec<BlockId> = Vec::new();
        let mut work: Vec<BlockId> = self.deps[b.index()].clone();
        while let Some(x) = work.pop() {
            if out.contains(&x) {
                continue;
            }
            out.push(x);
            for &d in &self.deps[x.index()] {
                if !out.contains(&d) {
                    work.push(d);
                }
            }
        }
        out
    }

    pub fn is_control_dependent(&self, b: BlockId, on: BlockId) -> bool {
        self.transitive(b).contains(&on)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_single;
    use crate::ir::BlockId;

    #[test]
    fn diamond_control_deps() {
        let (_, f) = parse_single(
            r#"
func @d(%c: b1) {
entry:
  condbr %c, left, right
left:
  br join
right:
  br join
join:
  ret
}
"#,
        )
        .unwrap();
        let cd = ControlDeps::new(&f);
        assert_eq!(cd.direct(BlockId(1)), &[BlockId(0)]); // left cd on entry
        assert_eq!(cd.direct(BlockId(2)), &[BlockId(0)]); // right cd on entry
        assert!(cd.direct(BlockId(3)).is_empty()); // join not cd
        assert!(cd.direct(BlockId(0)).is_empty());
    }

    #[test]
    fn nested_triangle_control_deps() {
        let (_, f) = parse_single(
            r#"
func @t(%c: b1) {
entry:
  condbr %c, outer, exit
outer:
  condbr %c, inner, join
inner:
  br join
join:
  br exit
exit:
  ret
}
"#,
        )
        .unwrap();
        let cd = ControlDeps::new(&f);
        // inner cd on outer; outer cd on entry; join cd on entry
        assert_eq!(cd.direct(BlockId(2)), &[BlockId(1)]);
        assert_eq!(cd.direct(BlockId(1)), &[BlockId(0)]);
        assert_eq!(cd.direct(BlockId(3)), &[BlockId(0)]);
        // inner transitively cd on entry
        let t = cd.transitive(BlockId(2));
        assert!(t.contains(&BlockId(0)) && t.contains(&BlockId(1)));
    }

    #[test]
    fn loop_body_control_dep_on_header() {
        let (_, f) = parse_single(
            r#"
func @l(%c: b1) {
entry:
  br header
header:
  condbr %c, body, exit
body:
  br header
exit:
  ret
}
"#,
        )
        .unwrap();
        let cd = ControlDeps::new(&f);
        // body cd on header; header cd on itself (loop-carried)
        assert_eq!(cd.direct(BlockId(2)), &[BlockId(1)]);
        assert!(cd.direct(BlockId(1)).contains(&BlockId(1)));
    }
}
