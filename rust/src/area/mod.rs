//! Analytical ALM area model, standing in for Quartus place-and-route
//! (paper §8.1: areas in Adaptive Logic Modules on an Intel Arria 10).
//!
//! The model charges:
//! - per-instruction datapath costs (64-bit adders/comparators ≈ 32
//!   ALMs, multiplier ALM-equivalent ≈ 150, divider ≈ 600, muxes ≈ 32,
//!   channel interfaces ≈ 24);
//! - per-block scheduler/FSM cost (the paper §8.3: "an increased number
//!   of blocks can result in a higher area usage due to larger scheduler
//!   complexity" [50]) — `BLOCK_BASE` plus a per-instruction control
//!   share;
//! - per-channel FIFO cost (depth × width packed into MLAB-equivalent
//!   ALMs);
//! - per-hazard-array LSQ cost (CAM-style store queue: `st_q` entries ×
//!   per-entry comparators, load queue bookkeeping) — the dominant DAE
//!   adder, matching Table 1's DAE ≈ 1.16× STA and SPEC ≈ 1.42× STA
//!   relative areas.
//!
//! Constants are calibrated to reproduce Table 1's *relative* areas, not
//! absolute Arria-10 numbers (we have no Quartus); Fig. 7's trend (< 5%
//! CU growth per poison block) emerges from `BLOCK_BASE` + poison-call
//! costs.

use crate::ir::{Function, Module, Op};
use crate::transform::Compiled;

// datapath costs (ALMs)
const ADD_SUB: usize = 32;
const LOGIC: usize = 16;
const CMP: usize = 20;
const MUX: usize = 32;
const MUL: usize = 150;
const DIV: usize = 600;
const CHAN_IF: usize = 24;
const CONST: usize = 0;
const CAST: usize = 24;

// control costs
const BLOCK_BASE: usize = 28;
const INSTR_CTRL: usize = 6;
/// Accelerator-shell overhead per unit (controller, start/done logic,
/// host interface share) — the bulk of the paper's STA baseline area.
const UNIT_BASE: usize = 700;

// memory system (the paper's HLS LSQ [54] is deliberately lightweight)
const FIFO_BASE: usize = 25;
const FIFO_PER_SLOT: usize = 2; // 64-bit slot in MLAB-equivalent ALMs
const LSQ_BASE: usize = 200;
const LSQ_PER_ST: usize = 6; // allocation entry: address tag + state
const LSQ_PER_LD: usize = 12;
const SRAM_PORT: usize = 90; // per-array port/arbitration logic
/// STA's conservative in-order memory unit per hazard array.
const IN_ORDER_MEM: usize = 400;

/// Area broken down by unit.
#[derive(Clone, Copy, Debug, Default)]
pub struct AreaEstimate {
    pub agu: usize,
    pub cu: usize,
    pub du: usize,
    pub total: usize,
}

fn op_cost(op: &Op) -> usize {
    use crate::ir::BinOp::*;
    match op {
        Op::ConstI(_) | Op::ConstF(_) | Op::ConstB(_) => CONST,
        Op::IBin(o, ..) | Op::FBin(o, ..) => match o {
            Mul => MUL,
            Div | Rem => DIV,
            Add | Sub | Min | Max => ADD_SUB,
            _ => LOGIC,
        },
        Op::ICmp(..) | Op::FCmp(..) => CMP,
        Op::Not(_) => 1,
        Op::Select { .. } => MUX,
        Op::IToF(_) | Op::FToI(_) => CAST,
        Op::Phi { .. } => MUX / 2,
        Op::Load { .. } | Op::Store { .. } => SRAM_PORT / 2,
        Op::SendLdAddr { .. }
        | Op::SendStAddr { .. }
        | Op::ConsumeVal { .. }
        | Op::ProduceVal { .. }
        | Op::PoisonVal { .. } => CHAN_IF,
    }
}

/// Area of one unit (function slice): datapath + scheduler.
pub fn function_area(f: &Function) -> usize {
    let reach = crate::transform::simplify_cfg::reachable_blocks(f);
    let mut area = UNIT_BASE;
    for (bi, b) in f.blocks.iter().enumerate() {
        if !reach[bi] {
            continue;
        }
        area += BLOCK_BASE;
        for &iid in &b.instrs {
            area += op_cost(&f.instr(iid).op) + INSTR_CTRL;
        }
    }
    area
}

/// Hazard arrays (stored anywhere) need an LSQ in the DU; read-only
/// arrays need only a stream port.
fn du_area(m: &Module, fs: &[&Function], chan_cap: usize, ld_q: usize, st_q: usize) -> usize {
    let mut stored = vec![false; m.arrays.len()];
    for f in fs {
        for b in &f.blocks {
            for &iid in &b.instrs {
                if let Op::SendStAddr { chan, .. } = f.instr(iid).op {
                    stored[m.chan(chan).arr.index()] = true;
                }
            }
        }
    }
    let mut area = 0;
    for (ai, _) in m.arrays.iter().enumerate() {
        if stored[ai] {
            area += LSQ_BASE + st_q * LSQ_PER_ST + ld_q * LSQ_PER_LD;
        } else {
            area += SRAM_PORT;
        }
    }
    // channel FIFOs — count only channels the slices still reference
    // after DCE (pruned consumes delete their stream)
    let mut used = vec![false; m.chans.len()];
    for f in fs {
        for b in &f.blocks {
            for &iid in &b.instrs {
                match f.instr(iid).op {
                    Op::SendLdAddr { chan, .. }
                    | Op::SendStAddr { chan, .. }
                    | Op::ConsumeVal { chan, .. }
                    | Op::ProduceVal { chan, .. }
                    | Op::PoisonVal { chan, .. } => used[chan.index()] = true,
                    _ => {}
                }
            }
        }
    }
    area += used.iter().filter(|&&u| u).count() * (FIFO_BASE + chan_cap * FIFO_PER_SLOT);
    area
}

/// Estimate the accelerator area for a compiled architecture using the
/// machine configuration's queue sizes.
pub fn estimate(c: &Compiled, cfg: &crate::sim::MachineConfig) -> AreaEstimate {
    match c {
        Compiled::Monolithic { module, .. } => {
            let f = &module.funcs[0];
            let mut a = AreaEstimate { cu: function_area(f), ..Default::default() };
            // STA: in-order disambiguation unit per hazard (stored) array,
            // plain port otherwise
            let mut stored = vec![false; module.arrays.len()];
            for b in &f.blocks {
                for &iid in &b.instrs {
                    if let Op::Store { arr, .. } = f.instr(iid).op {
                        stored[arr.index()] = true;
                    }
                }
            }
            a.du = stored
                .iter()
                .map(|&s| if s { IN_ORDER_MEM } else { SRAM_PORT })
                .sum();
            a.total = a.cu + a.du;
            a
        }
        Compiled::Dae { program, .. } => {
            let agu = program.agu_fn();
            let cu = program.cu_fn();
            let mut a = AreaEstimate {
                agu: function_area(agu),
                cu: function_area(cu),
                du: du_area(&program.module, &[agu, cu], cfg.chan_cap, cfg.ld_q, cfg.st_q),
                ..Default::default()
            };
            a.total = a.agu + a.cu + a.du;
            a
        }
    }
}

/// Paper-style relative area (normalised to a baseline total).
pub fn relative(a: AreaEstimate, base: AreaEstimate) -> f64 {
    a.total as f64 / base.total as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::MachineConfig;
    use crate::transform::{build, Arch};

    #[test]
    fn relative_areas_follow_table1_shape() {
        // DAE > STA (FIFOs + LSQ), SPEC ≥ DAE (poison logic), SPEC ≈ ORACLE
        let cfg = MachineConfig::default();
        let w = crate::workloads::build("hist", 3, None).unwrap();
        let mut areas = std::collections::HashMap::new();
        for arch in Arch::ALL {
            let c = build(&w.module, 0, arch).unwrap();
            areas.insert(arch, estimate(&c, &cfg).total);
        }
        assert!(areas[&Arch::Dae] > areas[&Arch::Sta]);
        // SPEC vs DAE can go either way (paper fw: SPEC 4008 < DAE 4210 —
        // hoisting deletes AGU blocks while the CU gains poison logic)
        let sd = areas[&Arch::Spec] as f64 / areas[&Arch::Dae] as f64;
        assert!((0.7..1.7).contains(&sd), "SPEC/DAE = {sd}");
        let spec = areas[&Arch::Spec] as f64;
        let oracle = areas[&Arch::Oracle] as f64;
        assert!(
            (spec / oracle - 1.0).abs() < 0.15,
            "SPEC {} vs ORACLE {} should be close",
            spec,
            oracle
        );
        // overall inflation sane (paper: SPEC ≈ 1.42× STA harmonic mean)
        let ratio = spec / areas[&Arch::Sta] as f64;
        assert!((1.05..2.5).contains(&ratio), "SPEC/STA = {ratio}");
    }

    #[test]
    fn poison_blocks_add_modest_cu_area() {
        // Fig. 7: each poison block adds a few percent of CU area
        let cfg = MachineConfig::default();
        let mut prev = None;
        for levels in [1usize, 4, 8] {
            let w = crate::workloads::nested::nested(levels, 3);
            let spec = build(&w.module, 0, Arch::Spec).unwrap();
            let a = estimate(&spec, &cfg);
            if let Some(p) = prev {
                assert!(a.cu >= p, "CU area should grow with nesting");
            }
            prev = Some(a.cu);
        }
    }

    #[test]
    fn area_is_deterministic() {
        let cfg = MachineConfig::default();
        let w = crate::workloads::build("mm", 5, None).unwrap();
        let c1 = build(&w.module, 0, Arch::Spec).unwrap();
        let c2 = build(&w.module, 0, Arch::Spec).unwrap();
        assert_eq!(estimate(&c1, &cfg).total, estimate(&c2, &cfg).total);
    }
}
