fn main() {
    let w = dae_spec::workloads::build("sssp", 1, None).unwrap();
    let spec = dae_spec::transform::build(&w.module, 0, dae_spec::transform::Arch::Spec).unwrap();
    let cfg = dae_spec::sim::MachineConfig::default();
    for _ in 0..5 {
        std::hint::black_box(dae_spec::sim::machine::simulate(&spec, &w.args, w.memory.clone(), &cfg).unwrap());
    }
}
