//! Deterministic fault injection for the DAE timing model.
//!
//! The paper's speculation mechanism is only trustworthy if poison-based
//! recovery preserves sequential consistency under *adversarial* timing,
//! not just the default latencies one seed happens to exercise. This
//! subsystem stresses exactly the places decoupled queue machines are
//! fragile — channel skew, LSQ pressure, SRAM latency spikes,
//! mis-speculation storms — while keeping every run replayable:
//!
//! - [`plan`] — seeded [`FaultPlan`] generation and the stateless
//!   [`FaultInjector`] the machine consults at its hook points
//!   (`Channels::push/pop`, LSQ admission, memory port grants);
//! - [`harness`] — the `dae-spec fuzz` differential harness: every plan
//!   runs across STA/DAE/SPEC and must match the reference interpreter
//!   bit-for-bit ([`crate::sim::memory_diff`]), with greedy
//!   minimization of failing plans.

pub mod harness;
pub mod plan;

pub use harness::{
    apply_semantic_mutation, check_plan, failure_perfetto, fuzz_kernel, fuzz_sweep,
    lint_cross_validate, minimize_plan, FuzzFailure, FuzzOutcome, SemanticMutation,
};
pub use plan::{FaultEvent, FaultInjector, FaultPlan, FaultSite};
