//! Deterministic fault plans and the injector the machine consults.
//!
//! A [`FaultPlan`] is a small list of [`FaultEvent`]s, each perturbing one
//! hook point of the timing model inside a cycle window. Plans are
//! generated from a seed with the repo PRNG, so `fuzz --seed S` replays
//! bit-identically; the injector itself is *stateless* — every hook is a
//! pure function of `(plan seed, site, timestamp)` — so a shared
//! `&MachineConfig` can carry it across the runner's scoped threads.
//!
//! Timing sites only stretch latencies and squeeze capacities; they can
//! never change a committed value, which is exactly what lets the fuzz
//! harness assert bit-exact memory equivalence against the reference
//! interpreter. The two *functional* sites (`WedgeConsume`,
//! `DropPoison`) exist for the robustness tests — a stall-forever fault
//! that must surface as a `StallDiagnostic`, and a deliberate
//! poison-drop bug the differential harness must catch — and are never
//! emitted by [`FaultPlan::generate`].

use crate::util::Rng;
use std::fmt;

/// A hook point in `sim/machine.rs` where a fault can act.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// Extra latency on a channel push (AGU requests, CU values, poisons,
    /// DU load-value delivery).
    ChanPushDelay,
    /// Extra stall cycles on a `consume_val` pop.
    ChanPopStall,
    /// Extra SRAM read latency (STA loads and DU load issue).
    MemReadDelay,
    /// Extra SRAM write latency (STA stores and DU store commit).
    MemWriteDelay,
    /// Squeeze the LSQ load queue down to `magnitude` entries (floor 1).
    LsqLoadSqueeze,
    /// Squeeze the LSQ store queue down to `magnitude` entries (floor 1).
    LsqStoreSqueeze,
    /// Extra busy cycles on the STA machine's per-array read port (the
    /// port-conflict serialization of the static-schedule model).
    StaReadPortStall,
    /// Extra busy cycles on the STA machine's per-array write port.
    StaWritePortStall,
    /// FUNCTIONAL (test-only): block every `consume_val` whose operand has
    /// arrived — wedges the machine so the deadlock watchdog must fire.
    WedgeConsume,
    /// FUNCTIONAL (test-only): the DU ignores the poison bit and commits
    /// the placeholder value — the injected mis-speculation-recovery bug
    /// the differential fuzz harness is required to catch.
    DropPoison,
}

impl FaultSite {
    /// All sites that only perturb timing (safe for equivalence fuzzing).
    pub const TIMING: [FaultSite; 8] = [
        FaultSite::ChanPushDelay,
        FaultSite::ChanPopStall,
        FaultSite::MemReadDelay,
        FaultSite::MemWriteDelay,
        FaultSite::LsqLoadSqueeze,
        FaultSite::LsqStoreSqueeze,
        FaultSite::StaReadPortStall,
        FaultSite::StaWritePortStall,
    ];

    pub fn is_timing_only(self) -> bool {
        !matches!(self, FaultSite::WedgeConsume | FaultSite::DropPoison)
    }

    /// Stable tag mixed into the jitter hash.
    fn tag(self) -> u64 {
        match self {
            FaultSite::ChanPushDelay => 1,
            FaultSite::ChanPopStall => 2,
            FaultSite::MemReadDelay => 3,
            FaultSite::MemWriteDelay => 4,
            FaultSite::LsqLoadSqueeze => 5,
            FaultSite::LsqStoreSqueeze => 6,
            FaultSite::WedgeConsume => 7,
            FaultSite::DropPoison => 8,
            FaultSite::StaReadPortStall => 9,
            FaultSite::StaWritePortStall => 10,
        }
    }
}

/// One fault, active for timestamps in `[from, until)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    pub site: FaultSite,
    pub from: u64,
    pub until: u64,
    /// Delay amplitude in cycles for latency sites; target capacity for
    /// squeeze sites; ignored (any non-zero) for the functional sites.
    pub magnitude: u64,
}

impl fmt::Display for FaultEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:?}@[{},{})x{}", self.site, self.from, self.until, self.magnitude)
    }
}

/// A deterministic, replayable fault schedule plus an optional
/// mis-speculation storm (override of the workload generator's
/// mis-speculation-rate knob, aimed at the speculated store ops).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Seed of this plan: drives both the event schedule, the jitter
    /// hash, and the workload data the fuzz harness generates.
    pub seed: u64,
    /// Index within the fuzz batch (printed for reproduction).
    pub index: u64,
    pub events: Vec<FaultEvent>,
    /// Mis-speculation-rate override for kernels that support the knob
    /// (hist/thr/mm/spmv); `None` keeps the kernel default.
    pub misspec: Option<f64>,
}

fn mix(mut x: u64) -> u64 {
    // splitmix64 finalizer — cheap, well-distributed, no state
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

impl FaultPlan {
    /// Empty plan (no faults) with a given seed — the clean baseline.
    pub fn clean(seed: u64) -> FaultPlan {
        FaultPlan { seed, index: 0, events: Vec::new(), misspec: None }
    }

    /// Generate the `index`-th plan of a `base_seed` batch: 1–5 timing
    /// events plus an optional mis-speculation storm. Deterministic.
    pub fn generate(base_seed: u64, index: u64) -> FaultPlan {
        let seed = mix(base_seed ^ mix(index.wrapping_add(1)));
        let mut rng = Rng::new(seed);
        let n = 1 + rng.below(5) as usize;
        let events = (0..n)
            .map(|_| {
                let site = FaultSite::TIMING[rng.below(FaultSite::TIMING.len() as u64) as usize];
                let from = rng.below(30_000);
                let until = from + 1 + rng.below(10_000);
                let magnitude = match site {
                    FaultSite::LsqLoadSqueeze | FaultSite::LsqStoreSqueeze => 1 + rng.below(4),
                    _ => 1 + rng.below(24),
                };
                FaultEvent { site, from, until, magnitude }
            })
            .collect();
        // mis-speculation storm: half the plans pin the rate to an extreme
        const RATES: [f64; 7] = [0.0, 0.1, 0.3, 0.5, 0.7, 0.9, 1.0];
        let misspec = rng.chance(0.5).then(|| RATES[rng.below(RATES.len() as u64) as usize]);
        FaultPlan { seed, index, events, misspec }
    }

    /// A stall-forever plan: every consume wedges from cycle 0 on. Used
    /// by the watchdog/deadlock tests; never generated by `generate`.
    pub fn wedge() -> FaultPlan {
        FaultPlan {
            seed: 0,
            index: 0,
            events: vec![FaultEvent {
                site: FaultSite::WedgeConsume,
                from: 0,
                until: u64::MAX,
                magnitude: 1,
            }],
            misspec: None,
        }
    }

    /// Whether every event is timing-only (memory equivalence must hold).
    pub fn is_timing_only(&self) -> bool {
        self.events.iter().all(|e| e.site.is_timing_only())
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "seed=0x{:016x} misspec=", self.seed)?;
        match self.misspec {
            Some(r) => write!(f, "{r}")?,
            None => write!(f, "default")?,
        }
        write!(f, " events=[")?;
        for (i, e) in self.events.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{e}")?;
        }
        write!(f, "]")
    }
}

/// The stateless hook object the machine consults. Carried in
/// `MachineConfig`; `Clone + Send + Sync` so the runner's scoped threads
/// can share one config.
#[derive(Clone, Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Self {
        FaultInjector { plan }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Magnitude of the first event of `site` active at `t`.
    fn magnitude(&self, site: FaultSite, t: u64) -> Option<u64> {
        self.plan
            .events
            .iter()
            .find(|e| e.site == site && e.from <= t && t < e.until)
            .map(|e| e.magnitude)
    }

    /// Deterministic jitter in `[0, magnitude]` for `site` at `t`.
    fn jitter(&self, site: FaultSite, t: u64) -> u64 {
        match self.magnitude(site, t) {
            None | Some(0) => 0,
            Some(m) => mix(self.plan.seed ^ (site.tag() << 56) ^ t) % (m + 1),
        }
    }

    pub fn chan_push_delay(&self, t: u64) -> u64 {
        self.jitter(FaultSite::ChanPushDelay, t)
    }

    pub fn chan_pop_stall(&self, t: u64) -> u64 {
        self.jitter(FaultSite::ChanPopStall, t)
    }

    pub fn mem_read_extra(&self, t: u64) -> u64 {
        self.jitter(FaultSite::MemReadDelay, t)
    }

    pub fn mem_write_extra(&self, t: u64) -> u64 {
        self.jitter(FaultSite::MemWriteDelay, t)
    }

    /// Effective load-queue size at `t` (never below 1).
    pub fn ld_q(&self, base: usize, t: u64) -> usize {
        match self.magnitude(FaultSite::LsqLoadSqueeze, t) {
            Some(m) => base.min((m as usize).max(1)),
            None => base,
        }
    }

    /// Effective store-queue size at `t` (never below 1).
    pub fn st_q(&self, base: usize, t: u64) -> usize {
        match self.magnitude(FaultSite::LsqStoreSqueeze, t) {
            Some(m) => base.min((m as usize).max(1)),
            None => base,
        }
    }

    /// Extra busy-until cycles on the STA machine's per-array read port.
    pub fn sta_read_port_extra(&self, t: u64) -> u64 {
        self.jitter(FaultSite::StaReadPortStall, t)
    }

    /// Extra busy-until cycles on the STA machine's per-array write port.
    pub fn sta_write_port_extra(&self, t: u64) -> u64 {
        self.jitter(FaultSite::StaWritePortStall, t)
    }

    /// Functional: should a consume whose operand arrived at `t` wedge?
    pub fn wedge_consume(&self, t: u64) -> bool {
        self.magnitude(FaultSite::WedgeConsume, t).is_some()
    }

    /// Functional: should the DU drop the poison bit of a store value
    /// arriving at `t` (i.e. commit it — the injected bug)?
    pub fn drop_poison(&self, t: u64) -> bool {
        self.magnitude(FaultSite::DropPoison, t).is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        for i in 0..20 {
            assert_eq!(FaultPlan::generate(42, i), FaultPlan::generate(42, i));
        }
        assert_ne!(FaultPlan::generate(42, 0), FaultPlan::generate(42, 1));
        assert_ne!(FaultPlan::generate(42, 0), FaultPlan::generate(43, 0));
    }

    #[test]
    fn generated_plans_are_timing_only() {
        for i in 0..50 {
            let p = FaultPlan::generate(7, i);
            assert!(p.is_timing_only(), "plan {i} has a functional fault: {p}");
            assert!(!p.events.is_empty());
        }
    }

    #[test]
    fn jitter_respects_windows_and_amplitude() {
        let plan = FaultPlan {
            seed: 99,
            index: 0,
            events: vec![FaultEvent {
                site: FaultSite::MemReadDelay,
                from: 100,
                until: 200,
                magnitude: 7,
            }],
            misspec: None,
        };
        let inj = FaultInjector::new(plan);
        assert_eq!(inj.mem_read_extra(99), 0);
        assert_eq!(inj.mem_read_extra(200), 0);
        let mut any_nonzero = false;
        for t in 100..200 {
            let j = inj.mem_read_extra(t);
            assert!(j <= 7, "jitter {j} above amplitude at t={t}");
            assert_eq!(j, inj.mem_read_extra(t), "jitter must be pure in t");
            any_nonzero |= j > 0;
        }
        assert!(any_nonzero, "a 100-cycle burst at amplitude 7 must fire");
        // other sites are untouched
        assert_eq!(inj.chan_push_delay(150), 0);
    }

    #[test]
    fn squeezes_floor_at_one() {
        let plan = FaultPlan {
            seed: 1,
            index: 0,
            events: vec![FaultEvent {
                site: FaultSite::LsqStoreSqueeze,
                from: 0,
                until: u64::MAX,
                magnitude: 0,
            }],
            misspec: None,
        };
        let inj = FaultInjector::new(plan);
        assert_eq!(inj.st_q(32, 10), 1);
        assert_eq!(inj.ld_q(4, 10), 4, "load queue unaffected");
    }

    #[test]
    fn wedge_plan_blocks_consumes() {
        let inj = FaultInjector::new(FaultPlan::wedge());
        assert!(inj.wedge_consume(0));
        assert!(inj.wedge_consume(u64::MAX - 1));
        assert!(!inj.drop_poison(0));
    }
}
