//! Differential fuzz harness: run seeded fault plans across
//! architectures and assert bit-exact memory equivalence against the
//! reference interpreter.
//!
//! Timing-only plans (the only kind [`FaultPlan::generate`] emits) must
//! never change committed memory — the functional co-simulation order is
//! independent of timestamps by construction — so any divergence, stall,
//! or Lemma 6.1 (store-stream order) violation under such a plan is a
//! real machine bug. A failing plan is greedily minimized (drop events,
//! then the mis-speculation override, while the failure still
//! reproduces) and reported with the exact seed for replay.

use super::{FaultInjector, FaultPlan};
use crate::sim::{interpret, memory_diff, simulate, MachineConfig};
use crate::transform::{build, Arch};
use anyhow::{Context, Result};
use std::fmt;

/// One confirmed divergence: a plan × arch cell whose final memory (or
/// termination behaviour) departed from the reference interpreter.
#[derive(Debug)]
pub struct FuzzFailure {
    pub kernel: String,
    pub plan_index: u64,
    pub plan_seed: u64,
    pub base_seed: u64,
    pub arch: Arch,
    pub desc: String,
    /// Greedily shrunk plan that still reproduces the failure.
    pub minimized: FaultPlan,
}

impl fmt::Display for FuzzFailure {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "FAIL {}/{} plan #{} (seed=0x{:016x}): {}",
            self.kernel,
            self.arch.name(),
            self.plan_index,
            self.plan_seed,
            self.desc
        )?;
        writeln!(f, "  minimized: {}", self.minimized)?;
        write!(
            f,
            "  repro: dae-spec fuzz --kernel {} --seed {} --plans {} --arch {}",
            self.kernel,
            self.base_seed,
            self.plan_index + 1,
            self.arch.name()
        )
    }
}

/// Result of a fuzz batch.
#[derive(Debug)]
pub struct FuzzOutcome {
    pub kernel: String,
    pub plans: u64,
    pub archs: Vec<Arch>,
    pub failures: Vec<FuzzFailure>,
}

impl FuzzOutcome {
    pub fn ok(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Run one plan on one architecture. `Ok(None)` means the machine
/// terminated and matched the reference bit-for-bit; `Ok(Some(desc))`
/// describes a divergence (wrong memory, stall, or internal invariant
/// trip under the plan). `Err` is an infrastructure failure — the
/// workload or reference itself could not be built/run.
pub fn check_plan(
    kernel: &str,
    plan: &FaultPlan,
    arch: Arch,
    cfg: &MachineConfig,
) -> Result<Option<String>> {
    let w = crate::coordinator::build_workload(kernel, plan.seed, plan.misspec)?;
    let reference = interpret(
        &w.module,
        &w.module.funcs[0],
        &w.args,
        w.memory.clone(),
        cfg.max_dyn_instrs,
    )
    .with_context(|| format!("{kernel}: reference interpreter"))?;
    let c = build(&w.module, 0, arch).with_context(|| format!("{kernel}/{}", arch.name()))?;
    let mut fcfg = cfg.clone();
    fcfg.fault = Some(FaultInjector::new(plan.clone()));
    let sim = match simulate(&c, &w.args, w.memory.clone(), &fcfg) {
        Ok(s) => s,
        Err(e) => return Ok(Some(format!("simulation failed under plan: {e:#}"))),
    };
    if let Some((ai, i)) = memory_diff(&sim.memory, &reference.memory) {
        return Ok(Some(format!(
            "memory diverges at @{}[{}]: machine {} vs reference {}",
            w.module.arrays[ai].name, i, sim.memory[ai][i], reference.memory[ai][i]
        )));
    }
    Ok(None)
}

/// Greedily shrink a failing plan: drop events one at a time, then the
/// mis-speculation override, keeping each removal only if the failure
/// still reproduces on the same kernel × arch cell.
pub fn minimize_plan(
    kernel: &str,
    plan: &FaultPlan,
    arch: Arch,
    cfg: &MachineConfig,
) -> Result<FaultPlan> {
    let mut cur = plan.clone();
    let mut i = 0;
    while i < cur.events.len() {
        let mut cand = cur.clone();
        cand.events.remove(i);
        if check_plan(kernel, &cand, arch, cfg)?.is_some() {
            cur = cand;
        } else {
            i += 1;
        }
    }
    if cur.misspec.is_some() {
        let mut cand = cur.clone();
        cand.misspec = None;
        if check_plan(kernel, &cand, arch, cfg)?.is_some() {
            cur = cand;
        }
    }
    Ok(cur)
}

/// Run `plans` generated fault plans for `kernel` across `archs`,
/// collecting (and minimizing) every divergence.
pub fn fuzz_kernel(
    kernel: &str,
    base_seed: u64,
    plans: u64,
    archs: &[Arch],
    cfg: &MachineConfig,
    verbose: bool,
) -> Result<FuzzOutcome> {
    let mut failures = Vec::new();
    for index in 0..plans {
        let plan = FaultPlan::generate(base_seed, index);
        if verbose {
            println!("plan {:>3}/{plans}: {plan}", index + 1);
        }
        for &arch in archs {
            if let Some(desc) = check_plan(kernel, &plan, arch, cfg)? {
                let minimized = minimize_plan(kernel, &plan, arch, cfg)?;
                failures.push(FuzzFailure {
                    kernel: kernel.to_string(),
                    plan_index: index,
                    plan_seed: plan.seed,
                    base_seed,
                    arch,
                    desc,
                    minimized,
                });
            }
        }
    }
    Ok(FuzzOutcome { kernel: kernel.to_string(), plans, archs: archs.to_vec(), failures })
}
