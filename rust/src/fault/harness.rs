//! Differential fuzz harness: run seeded fault plans across
//! architectures and assert bit-exact memory equivalence against the
//! reference interpreter.
//!
//! Timing-only plans (the only kind [`FaultPlan::generate`] emits) must
//! never change committed memory — the functional co-simulation order is
//! independent of timestamps by construction — so any divergence, stall,
//! or Lemma 6.1 (store-stream order) violation under such a plan is a
//! real machine bug. A failing plan is greedily minimized (drop events,
//! then the mis-speculation override, while the failure still
//! reproduces) and reported with the exact seed for replay.

use super::{FaultInjector, FaultPlan};
use crate::ir::Op;
use crate::sim::{interpret, memory_diff, simulate, MachineConfig, SimSession};
use crate::transform::{build, Arch, Compiled, DaeProgram};
use crate::util::pool::parallel_map;
use anyhow::{bail, Context, Result};
use std::fmt;

/// One confirmed divergence: a plan × arch cell whose final memory (or
/// termination behaviour) departed from the reference interpreter.
#[derive(Debug)]
pub struct FuzzFailure {
    pub kernel: String,
    pub plan_index: u64,
    pub plan_seed: u64,
    pub base_seed: u64,
    pub arch: Arch,
    pub desc: String,
    /// Greedily shrunk plan that still reproduces the failure.
    pub minimized: FaultPlan,
}

impl fmt::Display for FuzzFailure {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "FAIL {}/{} plan #{} (seed=0x{:016x}): {}",
            self.kernel,
            self.arch.name(),
            self.plan_index,
            self.plan_seed,
            self.desc
        )?;
        writeln!(f, "  minimized: {}", self.minimized)?;
        write!(
            f,
            "  repro: dae-spec fuzz --kernel {} --seed {} --plans {} --arch {}",
            self.kernel,
            self.base_seed,
            self.plan_index + 1,
            self.arch.name()
        )
    }
}

/// Result of a fuzz batch.
#[derive(Debug)]
pub struct FuzzOutcome {
    pub kernel: String,
    pub plans: u64,
    pub archs: Vec<Arch>,
    pub failures: Vec<FuzzFailure>,
}

impl FuzzOutcome {
    pub fn ok(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Run one plan on one architecture. `Ok(None)` means the machine
/// terminated and matched the reference bit-for-bit; `Ok(Some(desc))`
/// describes a divergence (wrong memory, stall, or internal invariant
/// trip under the plan). `Err` is an infrastructure failure — the
/// workload or reference itself could not be built/run.
pub fn check_plan(
    kernel: &str,
    plan: &FaultPlan,
    arch: Arch,
    cfg: &MachineConfig,
) -> Result<Option<String>> {
    let w = crate::coordinator::build_workload(kernel, plan.seed, plan.misspec)?;
    let reference = interpret(
        &w.module,
        &w.module.funcs[0],
        &w.args,
        w.memory.clone(),
        cfg.max_dyn_instrs,
    )
    .with_context(|| format!("{kernel}: reference interpreter"))?;
    let c = build(&w.module, 0, arch).with_context(|| format!("{kernel}/{}", arch.name()))?;
    let mut fcfg = cfg.clone();
    fcfg.fault = Some(FaultInjector::new(plan.clone()));
    let sim = match simulate(&c, &w.args, w.memory.clone(), &fcfg) {
        Ok(s) => s,
        Err(e) => return Ok(Some(format!("simulation failed under plan: {e:#}"))),
    };
    if let Some((ai, i)) = memory_diff(&sim.memory, &reference.memory) {
        return Ok(Some(format!(
            "memory diverges at @{}[{}]: machine {} vs reference {}",
            w.module.arrays[ai].name, i, sim.memory[ai][i], reference.memory[ai][i]
        )));
    }
    Ok(None)
}

/// Re-run a confirmed failure's *minimized* plan with tracing and
/// metrics forced on and export the run as a Chrome/Perfetto
/// `trace_event` document (open at <https://ui.perfetto.dev>), so a
/// divergence ships with a visual timeline next to its replay seed.
/// The run is expected to fail or diverge again — the partial trace of
/// whatever executed is exactly what gets exported.
pub fn failure_perfetto(f: &FuzzFailure, cfg: &MachineConfig) -> Result<crate::util::Json> {
    let plan = &f.minimized;
    let w = crate::coordinator::build_workload(&f.kernel, plan.seed, plan.misspec)?;
    let c = build(&w.module, 0, f.arch)
        .with_context(|| format!("{}/{}", f.kernel, f.arch.name()))?;
    let mut fcfg = cfg.clone();
    fcfg.trace = true;
    fcfg.metrics = true;
    fcfg.fault = Some(FaultInjector::new(plan.clone()));
    let mut sess = SimSession::new(&c, &fcfg, w.memory.clone())?;
    let _ = sess.run(&w.args);
    let label = format!("{}/{} plan #{} (minimized)", f.kernel, f.arch.name(), f.plan_index);
    sess.perfetto(&label)
        .ok_or_else(|| anyhow::anyhow!("trace missing from re-profiled failure run"))
}

/// Greedily shrink a failing plan: drop events one at a time, then the
/// mis-speculation override, keeping each removal only if the failure
/// still reproduces on the same kernel × arch cell.
///
/// The event-drop phase re-runs one workload under many candidate
/// plans: the workload depends only on `plan.seed` / `plan.misspec`,
/// neither of which event removal touches, so the workload, reference
/// run and compiled program are built once and every candidate goes
/// through a single reused [`SimSession`] (zero-alloc steady state).
/// Dropping the misspec override *does* change the workload, so that
/// final probe goes through the full [`check_plan`] path.
pub fn minimize_plan(
    kernel: &str,
    plan: &FaultPlan,
    arch: Arch,
    cfg: &MachineConfig,
) -> Result<FaultPlan> {
    let mut cur = plan.clone();
    let w = crate::coordinator::build_workload(kernel, cur.seed, cur.misspec)?;
    let reference = interpret(
        &w.module,
        &w.module.funcs[0],
        &w.args,
        w.memory.clone(),
        cfg.max_dyn_instrs,
    )
    .with_context(|| format!("{kernel}: reference interpreter"))?;
    let c = build(&w.module, 0, arch).with_context(|| format!("{kernel}/{}", arch.name()))?;
    let mut sess = SimSession::new(&c, cfg, w.memory.clone())?;
    let mut i = 0;
    while i < cur.events.len() {
        let mut cand = cur.clone();
        cand.events.remove(i);
        sess.set_fault(Some(FaultInjector::new(cand.clone())));
        // same reproduction criterion as check_plan: a stall/invariant
        // trip under the plan counts, as does any memory divergence
        let reproduced = match sess.run(&w.args) {
            Err(_) => true,
            Ok(_) => memory_diff(sess.memory(), &reference.memory).is_some(),
        };
        if reproduced {
            cur = cand;
        } else {
            i += 1;
        }
    }
    if cur.misspec.is_some() {
        let mut cand = cur.clone();
        cand.misspec = None;
        if check_plan(kernel, &cand, arch, cfg)?.is_some() {
            cur = cand;
        }
    }
    Ok(cur)
}

/// Run `plans` generated fault plans for `kernel` across `archs`,
/// collecting (and minimizing) every divergence. Serial convenience
/// wrapper over [`fuzz_sweep`].
pub fn fuzz_kernel(
    kernel: &str,
    base_seed: u64,
    plans: u64,
    archs: &[Arch],
    cfg: &MachineConfig,
    verbose: bool,
) -> Result<FuzzOutcome> {
    let mut v =
        fuzz_sweep(&[kernel.to_string()], base_seed, plans, archs, cfg, 1, verbose)?;
    Ok(v.pop().expect("one kernel in, one outcome out"))
}

/// One (kernel, plan, arch) unit of fuzz work.
struct FuzzCell<'a> {
    kernel: &'a str,
    plan_index: u64,
    plan: &'a FaultPlan,
    arch: Arch,
}

/// Fan the full (kernel × plan × arch) grid across a bounded panic-safe
/// worker pool ([`parallel_map`]); `jobs == 1` is the serial sweep.
///
/// Results are **deterministic and job-count independent**: plan `i` is
/// always `FaultPlan::generate(base_seed, i)` (shared across kernels,
/// exactly as the serial per-kernel loop generated it), cells are
/// enumerated kernel-major then plan then arch — the old serial visit
/// order — and the pool merges results back by cell index, so the
/// returned outcomes and their failure order never depend on `jobs`.
/// A worker panic or infrastructure error fails the sweep, naming the
/// cell.
pub fn fuzz_sweep(
    kernels: &[String],
    base_seed: u64,
    plans: u64,
    archs: &[Arch],
    cfg: &MachineConfig,
    jobs: usize,
    verbose: bool,
) -> Result<Vec<FuzzOutcome>> {
    let plan_list: Vec<FaultPlan> =
        (0..plans).map(|i| FaultPlan::generate(base_seed, i)).collect();
    if verbose {
        for (i, plan) in plan_list.iter().enumerate() {
            println!("plan {:>3}/{plans}: {plan}", i + 1);
        }
    }
    let mut cells: Vec<FuzzCell> = Vec::with_capacity(kernels.len() * plan_list.len() * archs.len());
    for kernel in kernels {
        for (pi, plan) in plan_list.iter().enumerate() {
            for &arch in archs {
                cells.push(FuzzCell { kernel, plan_index: pi as u64, plan, arch });
            }
        }
    }
    let results = parallel_map(&cells, jobs, |_, cell| -> Result<Option<FuzzFailure>> {
        let Some(desc) = check_plan(cell.kernel, cell.plan, cell.arch, cfg)? else {
            return Ok(None);
        };
        let minimized = minimize_plan(cell.kernel, cell.plan, cell.arch, cfg)?;
        Ok(Some(FuzzFailure {
            kernel: cell.kernel.to_string(),
            plan_index: cell.plan_index,
            plan_seed: cell.plan.seed,
            base_seed,
            arch: cell.arch,
            desc,
            minimized,
        }))
    });

    let mut outcomes: Vec<FuzzOutcome> = kernels
        .iter()
        .map(|k| FuzzOutcome {
            kernel: k.clone(),
            plans,
            archs: archs.to_vec(),
            failures: Vec::new(),
        })
        .collect();
    let per_kernel = plan_list.len() * archs.len();
    for (i, r) in results.into_iter().enumerate() {
        let cell = &cells[i];
        match r {
            Err(panic) => bail!(
                "fuzz worker panicked on {}/{} plan #{}: {panic}",
                cell.kernel,
                cell.arch.name(),
                cell.plan_index
            ),
            Ok(Err(e)) => {
                return Err(e).with_context(|| {
                    format!(
                        "{}/{} plan #{}",
                        cell.kernel,
                        cell.arch.name(),
                        cell.plan_index
                    )
                })
            }
            Ok(Ok(None)) => {}
            Ok(Ok(Some(f))) => outcomes[i / per_kernel].failures.push(f),
        }
    }
    Ok(outcomes)
}

/// IR-level semantic mutations — the static analogues of the protocol
/// bugs the differential fuzzer hunts dynamically. Each deletes one
/// protocol-critical instruction from a compiled SPEC program; the
/// linter ([`crate::lint`]) must flag every one of them with an
/// Error-severity diagnostic, without running the machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SemanticMutation {
    /// Delete the first `poison_val` in the execute slice: a
    /// mis-speculated store would silently never be squashed
    /// (the DU pairing of Lemma 6.1 desynchronises).
    DropPoison,
    /// Delete the first `send_st_addr` in the access slice: a store
    /// request is never pushed, so the k-th value pairs with the
    /// (k+1)-th request.
    DropStorePush,
    /// Delete the first `produce_val` in the execute slice: a committed
    /// store loses its value.
    DropProduce,
}

impl SemanticMutation {
    pub const ALL: [SemanticMutation; 3] = [
        SemanticMutation::DropPoison,
        SemanticMutation::DropStorePush,
        SemanticMutation::DropProduce,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SemanticMutation::DropPoison => "drop-poison",
            SemanticMutation::DropStorePush => "drop-store-push",
            SemanticMutation::DropProduce => "drop-produce",
        }
    }
}

/// Apply `which` to `p`, returning a rendered description of the removed
/// instruction, or `None` if the program has no such instruction (e.g. a
/// kernel whose SPEC build needed no poisons).
pub fn apply_semantic_mutation(p: &mut DaeProgram, which: SemanticMutation) -> Option<String> {
    let (fi, want): (usize, fn(&Op) -> bool) = match which {
        SemanticMutation::DropPoison => (p.cu, |op| matches!(op, Op::PoisonVal { .. })),
        SemanticMutation::DropStorePush => (p.agu, |op| matches!(op, Op::SendStAddr { .. })),
        SemanticMutation::DropProduce => (p.cu, |op| matches!(op, Op::ProduceVal { .. })),
    };
    let mut target = None;
    'outer: for b in &p.module.funcs[fi].blocks {
        for &iid in &b.instrs {
            if want(&p.module.funcs[fi].instr(iid).op) {
                target = Some(iid);
                break 'outer;
            }
        }
    }
    let target = target?;
    let desc = crate::ir::printer::print_op(
        &p.module,
        &p.module.funcs[fi],
        &p.module.funcs[fi].instr(target).op,
    );
    crate::transform::detach_instr(&mut p.module.funcs[fi], target);
    Some(desc)
}

/// Cross-validate the linter against the mutation space: every
/// applicable [`SemanticMutation`] of `kernel`'s SPEC build must be
/// caught statically by [`crate::lint::lint_dae`]. Returns one
/// human-readable line per *uncaught* mutation (empty = full coverage).
pub fn lint_cross_validate(kernel: &str, seed: u64, verbose: bool) -> Result<Vec<String>> {
    let w = crate::coordinator::build_workload(kernel, seed, None)?;
    let c = build(&w.module, 0, Arch::Spec).with_context(|| format!("{kernel}/SPEC"))?;
    let Compiled::Dae { program, map, .. } = &c else {
        return Ok(vec![format!("{kernel}: SPEC build is not a decoupled program")]);
    };
    let mut uncaught = Vec::new();
    for mutation in SemanticMutation::ALL {
        let mut p = program.clone();
        let Some(removed) = apply_semantic_mutation(&mut p, mutation) else {
            if verbose {
                println!(
                    "lint-xval {kernel}: {} — no target instruction, skipped",
                    mutation.name()
                );
            }
            continue;
        };
        let rep = crate::lint::lint_dae(Some((&w.module, &w.module.funcs[0])), &p, map.as_ref());
        if rep.has_errors() {
            if verbose {
                println!(
                    "lint-xval {kernel}: {} caught ({} error(s)) after removing `{removed}`",
                    mutation.name(),
                    rep.count_at_least(crate::lint::Severity::Error)
                );
            }
        } else {
            uncaught.push(format!(
                "{kernel}: mutation {} (removed `{removed}`) produced no Error-severity \
                 lint diagnostic",
                mutation.name()
            ));
        }
    }
    Ok(uncaught)
}
