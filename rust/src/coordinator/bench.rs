//! `dae-spec bench` — host-side simulator throughput harness.
//!
//! Two phases per kernel × arch cell:
//!
//! 1. **Compile + validate** (parallel across cells, `--jobs N`, all
//!    cores by default): build the workload, compile, and run once so a
//!    cell that stalls or errors fails the harness before any timing.
//! 2. **Timing** (serial by default): repeated runs through one reused
//!    [`SimSession`] per cell, so the timed region contains only the
//!    machine — per-run buffer allocation and the old per-iteration
//!    `w.memory.clone()` are gone. `--time-jobs N` opts into timing
//!    cells concurrently; co-running cells contend for cores and
//!    inflate wall times, so never gate regressions on those numbers.
//!
//! Results go to `BENCH_sim.json` (schema `dae-spec-bench/v3`, which
//! adds a per-cell [`MetricsSummary`] collected during the phase-1
//! validation run — metrics stay *off* in the timed region; v2 added
//! `median_ns`; the baseline reader accepts v1–v3). Pass
//! `--baseline BENCH_sim.json --max-regress 10` to fail when a cell's
//! best time regresses by more than the given percentage, or
//! `--refresh-baseline` to rewrite the baseline from this run.

use crate::metrics::MetricsSummary;
use crate::sim::{MachineConfig, SimSession};
use crate::transform::{build, Arch, Compiled};
use crate::util::bench::BenchStats;
use crate::util::pool::parallel_map;
use crate::util::{Args, Bench, Json};
use crate::workloads::Workload;
use anyhow::{bail, Context, Result};

struct Cell {
    kernel: String,
    arch: &'static str,
    mean_ns: f64,
    stddev_ns: f64,
    min_ns: f64,
    median_ns: f64,
    cycles: u64,
    dyn_instrs: u64,
    metrics: Option<MetricsSummary>,
}

/// A compiled + validated cell, ready for the timing phase.
struct Prepared {
    kernel: String,
    arch: &'static str,
    w: Workload,
    c: Compiled,
    cycles: u64,
    dyn_instrs: u64,
    /// Telemetry from the validation run (the timing loop runs with
    /// metrics off).
    metrics: Option<MetricsSummary>,
}

pub fn cmd_bench(args: &Args) -> Result<()> {
    let seed = args.get_u64("seed", 2026);
    let warmup = args.get_u64("warmup", 2) as usize;
    let samples = (args.get_u64("samples", 10) as usize).max(1);
    let out_path = args.get("out").unwrap_or("BENCH_sim.json");
    let archs = super::parse_archs(Some(args.get("arch").unwrap_or("sta,dae,spec")))?;
    let kernels: Vec<String> = match args.get("kernels") {
        Some(list) => list.split(',').map(|s| s.trim().to_string()).collect(),
        None => crate::workloads::PAPER_KERNELS.iter().map(|s| s.to_string()).collect(),
    };

    let bench = Bench::new(warmup, samples);
    let cfg = MachineConfig::default();

    // phase 1: compile + validate every cell, fanned across the pool
    let specs: Vec<(String, Arch)> = kernels
        .iter()
        .flat_map(|k| archs.iter().map(move |&a| (k.clone(), a)))
        .collect();
    let jobs = args.get_jobs();
    let results = parallel_map(&specs, jobs, |_, (kernel, arch)| -> Result<Prepared> {
        let w = super::build_workload(kernel, seed, None)
            .with_context(|| format!("bench: building workload {kernel}"))?;
        let c = build(&w.module, 0, *arch)
            .with_context(|| format!("bench: compiling {kernel}/{}", arch.name()))?;
        // one validated run up front: a cell that stalls or errors
        // should fail the harness, not poison the timing loop. Metrics
        // are collected here (and only here — the timed sessions below
        // run with them off) so BENCH_sim.json carries a per-cell
        // MetricsSummary at zero cost to the measured numbers.
        let (cycles, dyn_instrs, metrics) = {
            let mut mcfg = cfg.clone();
            mcfg.metrics = true;
            let mut sess = SimSession::new(&c, &mcfg, w.memory.clone())?;
            let first = sess
                .run(&w.args)
                .with_context(|| format!("bench: {kernel}/{}", arch.name()))?;
            (first.cycles, first.dyn_instrs, sess.metrics_summary().cloned())
        };
        Ok(Prepared {
            kernel: kernel.clone(),
            arch: arch.name(),
            w,
            c,
            cycles,
            dyn_instrs,
            metrics,
        })
    });
    let mut prepared = Vec::with_capacity(specs.len());
    for (r, (kernel, arch)) in results.into_iter().zip(&specs) {
        match r {
            Ok(Ok(p)) => prepared.push(p),
            Ok(Err(e)) => return Err(e),
            Err(panic) => bail!("bench: {kernel}/{} panicked: {panic}", arch.name()),
        }
    }

    // phase 2: timing. One session per cell, allocated before the timed
    // region: the closure `Bench` times performs no heap allocation and
    // no `w.memory.clone()` (the old harness cloned memory inside the
    // timed closure, attributing a host alloc+memcpy to sim throughput —
    // the session restores its retained buffer instead, pinned
    // bit-identical to a fresh simulate by rust/tests/determinism.rs).
    let time_one = |p: &Prepared| -> BenchStats {
        let mut sess = SimSession::new(&p.c, &cfg, p.w.memory.clone())
            .expect("session allocation for a validated cell");
        let label = format!("{}/{}", p.kernel, p.arch);
        bench.run(&label, || {
            sess.run(&p.w.args).expect("validated cell failed during timing loop")
        })
    };
    let time_jobs =
        args.get("time-jobs").and_then(|s| s.parse::<usize>().ok()).unwrap_or(1).max(1);
    let timed: Vec<BenchStats> = if time_jobs > 1 {
        println!(
            "note: --time-jobs {time_jobs} times cells concurrently; co-running cells \
             contend for cores and inflate wall times — do not gate regressions on this run"
        );
        let rs = parallel_map(&prepared, time_jobs, |_, p| time_one(p));
        let mut v = Vec::with_capacity(prepared.len());
        for (r, p) in rs.into_iter().zip(&prepared) {
            match r {
                Ok(s) => v.push(s),
                Err(panic) => bail!("bench: timing {}/{} panicked: {panic}", p.kernel, p.arch),
            }
        }
        v
    } else {
        prepared.iter().map(time_one).collect()
    };

    let mut cells: Vec<Cell> = Vec::with_capacity(prepared.len());
    let mut total_instrs = 0.0;
    let mut total_secs = 0.0;
    for (p, stats) in prepared.iter().zip(&timed) {
        total_instrs += p.dyn_instrs as f64;
        total_secs += stats.min_ns / 1e9;
        cells.push(Cell {
            kernel: p.kernel.clone(),
            arch: p.arch,
            mean_ns: stats.mean_ns,
            stddev_ns: stats.stddev_ns,
            min_ns: stats.min_ns,
            median_ns: stats.median_ns,
            cycles: p.cycles,
            dyn_instrs: p.dyn_instrs,
            metrics: p.metrics.clone(),
        });
    }

    println!();
    for c in &cells {
        let ips = c.dyn_instrs as f64 / (c.min_ns / 1e9);
        println!(
            "{:<12} {:<7} {:>12} cycles  {:>12} instrs  {:>9.2} M sim-instrs/s",
            c.kernel,
            c.arch,
            c.cycles,
            c.dyn_instrs,
            ips / 1e6
        );
    }
    if total_secs > 0.0 {
        println!(
            "\naggregate: {:.2} M simulated instrs/s over {} cell(s)",
            total_instrs / total_secs / 1e6,
            cells.len()
        );
    }

    let doc = render_json(seed, warmup, samples, &cells);
    std::fs::write(out_path, doc.render())
        .with_context(|| format!("bench: writing {out_path}"))?;
    println!("wrote {out_path}");

    let baseline_path = args.get("baseline");
    if args.has_flag("refresh-baseline") {
        // overwrite the committed baseline with this run's measurements
        // (the gate is skipped — this run *defines* the new baseline)
        let path = baseline_path.unwrap_or("BENCH_baseline.json");
        std::fs::write(path, doc.render())
            .with_context(|| format!("bench: refreshing baseline {path}"))?;
        println!("refreshed baseline {path}");
    } else if let Some(path) = baseline_path {
        let pct = args.get_f64("max-regress", 10.0);
        compare_baseline(path, pct, &cells)?;
    }
    Ok(())
}

fn render_json(seed: u64, warmup: usize, samples: usize, cells: &[Cell]) -> Json {
    let results = cells
        .iter()
        .map(|c| {
            let ips = c.dyn_instrs as f64 / (c.min_ns / 1e9);
            let mut fields = vec![
                ("kernel".into(), Json::Str(c.kernel.clone())),
                ("arch".into(), Json::Str(c.arch.into())),
                ("mean_ns".into(), Json::Num(c.mean_ns)),
                ("stddev_ns".into(), Json::Num(c.stddev_ns)),
                ("min_ns".into(), Json::Num(c.min_ns)),
                ("median_ns".into(), Json::Num(c.median_ns)),
                ("cycles".into(), Json::Num(c.cycles as f64)),
                ("dyn_instrs".into(), Json::Num(c.dyn_instrs as f64)),
                ("sim_instrs_per_sec".into(), Json::Num(ips)),
            ];
            if let Some(m) = &c.metrics {
                fields.push(("metrics".into(), m.to_json()));
            }
            Json::Obj(fields)
        })
        .collect();
    Json::Obj(vec![
        ("schema".into(), Json::Str("dae-spec-bench/v3".into())),
        ("seed".into(), Json::Num(seed as f64)),
        ("warmup".into(), Json::Num(warmup as f64)),
        ("samples".into(), Json::Num(samples as f64)),
        ("results".into(), Json::Arr(results)),
    ])
}

/// Compare against a previously written bench file: a cell regresses
/// when its best (min) time exceeds the baseline's by more than `pct`
/// percent. Accepts schemas v1–v3 (v1 predates `median_ns`, v3 adds
/// per-cell `metrics`; the gate only reads `min_ns`, present in all).
/// Cells missing from the baseline are skipped, so growing the suite
/// never breaks the gate.
fn compare_baseline(path: &str, pct: f64, cells: &[Cell]) -> Result<()> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("bench: reading baseline {path}"))?;
    let doc = Json::parse(&text).with_context(|| format!("bench: parsing baseline {path}"))?;
    let schema = doc.get("schema").and_then(Json::as_str);
    if !matches!(
        schema,
        Some("dae-spec-bench/v1") | Some("dae-spec-bench/v2") | Some("dae-spec-bench/v3")
    ) {
        bail!("bench: {path} is not a dae-spec-bench/v1, /v2 or /v3 file");
    }
    let baseline = doc.get("results").and_then(Json::as_arr).unwrap_or(&[]);
    let mut regressions = Vec::new();
    let mut compared = 0;
    for c in cells {
        let old = baseline.iter().find(|r| {
            r.get("kernel").and_then(Json::as_str) == Some(c.kernel.as_str())
                && r.get("arch").and_then(Json::as_str) == Some(c.arch)
        });
        let Some(old_min) = old.and_then(|r| r.get("min_ns")).and_then(Json::as_f64) else {
            continue;
        };
        compared += 1;
        if c.min_ns > old_min * (1.0 + pct / 100.0) {
            regressions.push(format!(
                "  {}/{}: {:.2} ms -> {:.2} ms (+{:.1}%)",
                c.kernel,
                c.arch,
                old_min / 1e6,
                c.min_ns / 1e6,
                (c.min_ns / old_min - 1.0) * 100.0
            ));
        }
    }
    if regressions.is_empty() {
        println!("baseline: {compared} cell(s) within {pct}% of {path}");
        Ok(())
    } else {
        bail!(
            "bench: {} cell(s) regressed by more than {pct}% vs {path}:\n{}",
            regressions.len(),
            regressions.join("\n")
        )
    }
}
