//! `dae-spec bench` — host-side simulator throughput harness.
//!
//! Compiles each kernel × arch cell once, validates it with a first
//! simulation (reference-checked timing inputs come from the workload
//! builders), then times repeated `simulate` calls with [`Bench`].
//! Results go to `BENCH_sim.json` (schema `dae-spec-bench/v1`); pass
//! `--baseline BENCH_sim.json --max-regress 10` to fail when a cell's
//! best time regresses by more than the given percentage.

use crate::sim::MachineConfig;
use crate::transform::build;
use crate::util::{Args, Bench, Json};
use anyhow::{bail, Context, Result};

struct Cell {
    kernel: String,
    arch: &'static str,
    mean_ns: f64,
    stddev_ns: f64,
    min_ns: f64,
    cycles: u64,
    dyn_instrs: u64,
}

pub fn cmd_bench(args: &Args) -> Result<()> {
    let seed = args.get_u64("seed", 2026);
    let warmup = args.get_u64("warmup", 2) as usize;
    let samples = (args.get_u64("samples", 10) as usize).max(1);
    let out_path = args.get("out").unwrap_or("BENCH_sim.json");
    let archs = super::parse_archs(Some(args.get("arch").unwrap_or("sta,dae,spec")))?;
    let kernels: Vec<String> = match args.get("kernels") {
        Some(list) => list.split(',').map(|s| s.trim().to_string()).collect(),
        None => crate::workloads::PAPER_KERNELS.iter().map(|s| s.to_string()).collect(),
    };

    let bench = Bench::new(warmup, samples);
    let cfg = MachineConfig::default();
    let mut cells: Vec<Cell> = Vec::new();
    let mut total_instrs = 0.0;
    let mut total_secs = 0.0;

    for kernel in &kernels {
        let w = super::build_workload(kernel, seed, None)
            .with_context(|| format!("bench: building workload {kernel}"))?;
        for &arch in &archs {
            let c = build(&w.module, 0, arch)
                .with_context(|| format!("bench: compiling {kernel}/{}", arch.name()))?;
            // one validated run up front: a cell that stalls or errors
            // should fail the harness, not poison the timing loop
            let first = crate::sim::simulate(&c, &w.args, w.memory.clone(), &cfg)
                .with_context(|| format!("bench: {kernel}/{}", arch.name()))?;
            let label = format!("{kernel}/{}", arch.name());
            let stats = bench.run(&label, || {
                crate::sim::simulate(&c, &w.args, w.memory.clone(), &cfg)
                    .expect("validated cell failed during timing loop")
            });
            total_instrs += first.dyn_instrs as f64;
            total_secs += stats.min_ns / 1e9;
            cells.push(Cell {
                kernel: kernel.clone(),
                arch: arch.name(),
                mean_ns: stats.mean_ns,
                stddev_ns: stats.stddev_ns,
                min_ns: stats.min_ns,
                cycles: first.cycles,
                dyn_instrs: first.dyn_instrs,
            });
        }
    }

    println!();
    for c in &cells {
        let ips = c.dyn_instrs as f64 / (c.min_ns / 1e9);
        println!(
            "{:<12} {:<7} {:>12} cycles  {:>12} instrs  {:>9.2} M sim-instrs/s",
            c.kernel,
            c.arch,
            c.cycles,
            c.dyn_instrs,
            ips / 1e6
        );
    }
    if total_secs > 0.0 {
        println!(
            "\naggregate: {:.2} M simulated instrs/s over {} cell(s)",
            total_instrs / total_secs / 1e6,
            cells.len()
        );
    }

    let doc = render_json(seed, warmup, samples, &cells);
    std::fs::write(out_path, doc.render())
        .with_context(|| format!("bench: writing {out_path}"))?;
    println!("wrote {out_path}");

    if let Some(baseline_path) = args.get("baseline") {
        let pct = args.get_f64("max-regress", 10.0);
        compare_baseline(baseline_path, pct, &cells)?;
    }
    Ok(())
}

fn render_json(seed: u64, warmup: usize, samples: usize, cells: &[Cell]) -> Json {
    let results = cells
        .iter()
        .map(|c| {
            let ips = c.dyn_instrs as f64 / (c.min_ns / 1e9);
            Json::Obj(vec![
                ("kernel".into(), Json::Str(c.kernel.clone())),
                ("arch".into(), Json::Str(c.arch.into())),
                ("mean_ns".into(), Json::Num(c.mean_ns)),
                ("stddev_ns".into(), Json::Num(c.stddev_ns)),
                ("min_ns".into(), Json::Num(c.min_ns)),
                ("cycles".into(), Json::Num(c.cycles as f64)),
                ("dyn_instrs".into(), Json::Num(c.dyn_instrs as f64)),
                ("sim_instrs_per_sec".into(), Json::Num(ips)),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("schema".into(), Json::Str("dae-spec-bench/v1".into())),
        ("seed".into(), Json::Num(seed as f64)),
        ("warmup".into(), Json::Num(warmup as f64)),
        ("samples".into(), Json::Num(samples as f64)),
        ("results".into(), Json::Arr(results)),
    ])
}

/// Compare against a previously written `BENCH_sim.json`: a cell
/// regresses when its best (min) time exceeds the baseline's by more
/// than `pct` percent. Cells missing from the baseline are skipped, so
/// growing the suite never breaks the gate.
fn compare_baseline(path: &str, pct: f64, cells: &[Cell]) -> Result<()> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("bench: reading baseline {path}"))?;
    let doc = Json::parse(&text).with_context(|| format!("bench: parsing baseline {path}"))?;
    if doc.get("schema").and_then(Json::as_str) != Some("dae-spec-bench/v1") {
        bail!("bench: {path} is not a dae-spec-bench/v1 file");
    }
    let baseline = doc.get("results").and_then(Json::as_arr).unwrap_or(&[]);
    let mut regressions = Vec::new();
    let mut compared = 0;
    for c in cells {
        let old = baseline.iter().find(|r| {
            r.get("kernel").and_then(Json::as_str) == Some(c.kernel.as_str())
                && r.get("arch").and_then(Json::as_str) == Some(c.arch)
        });
        let Some(old_min) = old.and_then(|r| r.get("min_ns")).and_then(Json::as_f64) else {
            continue;
        };
        compared += 1;
        if c.min_ns > old_min * (1.0 + pct / 100.0) {
            regressions.push(format!(
                "  {}/{}: {:.2} ms -> {:.2} ms (+{:.1}%)",
                c.kernel,
                c.arch,
                old_min / 1e6,
                c.min_ns / 1e6,
                (c.min_ns / old_min - 1.0) * 100.0
            ));
        }
    }
    if regressions.is_empty() {
        println!("baseline: {compared} cell(s) within {pct}% of {path}");
        Ok(())
    } else {
        bail!(
            "bench: {} cell(s) regressed by more than {pct}% vs {path}:\n{}",
            regressions.len(),
            regressions.join("\n")
        )
    }
}
