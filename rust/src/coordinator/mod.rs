//! Experiment coordination: threaded runs across kernels ×
//! architectures, paper-format reports, and the CLI entrypoint.

pub mod bench;
pub mod profile;
pub mod report;
pub mod runner;

pub use runner::{run_kernel, run_suite, ExperimentRow, SuiteFailure, SuiteOutcome};

use crate::util::Args;

const USAGE: &str = "\
dae-spec — compiler support for speculation in DAE architectures (CC'25 reproduction)

USAGE:
  dae-spec repro <table1|table2|fig2|fig6|fig7|all> [--seed N]
  dae-spec run --kernel <name> [--arch sta|dae|spec|oracle] [--seed N]
               [--misspec R] [--trace] [--watchdog N] [--timeout-ms MS]
  dae-spec fuzz [--kernel hist|all] [--plans 25] [--seed N] [--arch sta,dae,spec]
                [--jobs N] [--watchdog N] [--timeout-ms MS] [--verbose]
                differential fault-injection fuzzing: each plan perturbs
                timing only (SRAM latency spikes, channel push/pop jitter,
                LSQ load/store-queue squeezes, mis-speculation storms), so
                final memory must stay bit-identical to the reference
                interpreter; failing plans are minimized and printed with
                their replay seed. --jobs N fans the kernel x plan x arch
                grid across a panic-safe worker pool (0 or absent = all
                cores); results are identical for every job count
  dae-spec bench [--kernels hist,thr,...] [--arch sta,dae,spec] [--seed N]
                 [--samples 10] [--warmup 2] [--out BENCH_sim.json]
                 [--baseline BENCH_sim.json] [--max-regress 10]
                 [--jobs N] [--time-jobs N] [--refresh-baseline]
                 host-side simulator throughput per kernel x arch via a
                 reused SimSession per cell (memory restore is outside the
                 timed region); writes BENCH_sim.json (schema v3, adds a
                 per-cell metrics summary from the validation run;
                 v1/v2 baselines still read) and (with --baseline)
                 fails if any cell's best time regresses by more than
                 --max-regress percent. --jobs parallelizes the
                 compile+validate phase only; --time-jobs N also times
                 cells concurrently (opt-in: co-running cells contend for
                 cores and inflate wall times — keep serial for gating).
                 --refresh-baseline rewrites the baseline file from this
                 run's measurements
  dae-spec profile [--kernel hist] [--arch sta,dae,spec] [--seed N]
                   [--misspec R] [--json] [--out PROFILE.json]
                   [--perfetto BASE.json] [--watchdog N] [--timeout-ms MS]
                   run one kernel with the metrics layer on and report
                   per-unit busy/blocked cycles, channel occupancy, LSQ
                   residency, decoupling slack (AGU lead over the CU),
                   MLP and speculation/poison counters. --json prints the
                   dae-spec-profile/v1 document (--out writes it);
                   --perfetto BASE.json writes one Chrome/Perfetto
                   trace-event file per arch (BASE.<arch>.json) — open at
                   https://ui.perfetto.dev
  dae-spec lint [--kernel <name>|all] [--arch sta,dae,spec] [--seed N]
                [--deny error|warn|info] [--verbose]
                static semantic verification of compiled slices: decoupling
                legality (DEC), channel push/pop balance per path and per
                iteration (CHAN), poison coverage + speculative-value taint
                (POISON), and store-order/SC preservation (SC); exits
                non-zero if any diagnostic at or above --deny fires
                (default error; --verbose also prints info notes)
  dae-spec compile --kernel <name> [--arch ...]      dump transformed IR
  dae-spec lsq-sweep [--kernel bfs] [--sizes 4,8,16,32,64]
  dae-spec list                                      list kernels

Watchdog knobs (MachineConfig): --watchdog N aborts after N scheduler
rounds with no timestamp/instruction advance (default 10000, 0 = off);
--timeout-ms MS is a cooperative wall-clock budget per simulation
(default 0 = off). Both produce a structured stall diagnostic listing
per-unit t_ctrl, channel occupancy/last-push/last-pop, and LSQ fill.

Kernels: bfs bc sssp hist thr mm fw sort spmv nested<1-8>
";

/// CLI dispatcher (kept in the library so it is testable).
pub fn cli_main(argv: Vec<String>) -> i32 {
    let args = Args::parse(&argv, &["trace", "no-check", "verbose", "refresh-baseline", "json"]);
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "repro" => cmd_repro(&args),
        "run" => cmd_run(&args),
        "fuzz" => cmd_fuzz(&args),
        "lint" => cmd_lint(&args),
        "bench" => bench::cmd_bench(&args),
        "profile" => profile::cmd_profile(&args),
        "compile" => cmd_compile(&args),
        "lsq-sweep" => cmd_lsq_sweep(&args),
        "list" => {
            for k in crate::workloads::PAPER_KERNELS {
                println!("{k}");
            }
            Ok(())
        }
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    };
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            report::print_stall(&e);
            1
        }
    }
}

/// Apply the shared watchdog/timeout CLI knobs to a machine config.
fn apply_watchdog_knobs(cfg: &mut crate::sim::MachineConfig, args: &Args) {
    cfg.watchdog_rounds = args.get_u64("watchdog", cfg.watchdog_rounds);
    cfg.wall_timeout_ms = args.get_u64("timeout-ms", cfg.wall_timeout_ms);
}

fn cmd_fuzz(args: &Args) -> anyhow::Result<()> {
    let kernel = args.get("kernel").unwrap_or("hist");
    let seed = args.get_u64("seed", 2026);
    let plans = args.get_u64("plans", 25);
    let archs = parse_archs(Some(args.get("arch").unwrap_or("sta,dae,spec")))?;
    if archs.contains(&crate::transform::Arch::Oracle) {
        anyhow::bail!("fuzz: ORACLE diverges from the reference by design; pick sta/dae/spec");
    }
    let mut cfg = crate::sim::MachineConfig::default();
    apply_watchdog_knobs(&mut cfg, args);
    // `--kernel all` sweeps every paper kernel plus a nested-if
    // workload, so timing perturbations are differentially checked on
    // every control-flow shape the suite exercises.
    let kernels: Vec<String> = if kernel == "all" {
        let mut ks: Vec<String> =
            crate::workloads::PAPER_KERNELS.iter().map(|s| s.to_string()).collect();
        ks.push("nested3".to_string());
        ks
    } else {
        vec![kernel.to_string()]
    };
    let mut diverged = 0usize;
    let mut cells = 0usize;
    let mut uncaught = 0usize;
    for kernel in &kernels {
        // Static/dynamic cross-validation (SPEC only): every semantic
        // mutation the fuzzer could inject must also be flagged by the
        // linter without running the machine.
        if archs.contains(&crate::transform::Arch::Spec) {
            let misses =
                crate::fault::lint_cross_validate(kernel, seed, args.has_flag("verbose"))?;
            for m in &misses {
                eprintln!("lint-xval MISS {m}");
            }
            uncaught += misses.len();
        }
    }
    // The kernel x plan x arch grid fans across the worker pool; the
    // sweep is bit-identical for every --jobs value (pinned by
    // rust/tests/fault_fuzz.rs).
    let jobs = args.get_jobs();
    let outcomes = crate::fault::fuzz_sweep(
        &kernels,
        seed,
        plans,
        &archs,
        &cfg,
        jobs,
        args.has_flag("verbose"),
    )?;
    for out in &outcomes {
        let arch_names: Vec<&str> = out.archs.iter().map(|a| a.name()).collect();
        cells += out.plans as usize * out.archs.len();
        if out.ok() {
            println!(
                "fuzz: {} plan(s) x [{}] on {} — no divergence from reference (seed {seed})",
                out.plans,
                arch_names.join(","),
                out.kernel
            );
        } else {
            for f in &out.failures {
                eprintln!("{f}");
                // dump a Perfetto trace of the minimized plan next to
                // the replay seed; best-effort — a trace export failure
                // must not mask the divergence report
                let path = format!(
                    "fuzz_fail_{}_{}_plan{}.perfetto.json",
                    f.kernel,
                    f.arch.name().to_lowercase(),
                    f.plan_index
                );
                match crate::fault::failure_perfetto(f, &cfg) {
                    Ok(doc) => match std::fs::write(&path, doc.render()) {
                        Ok(()) => {
                            eprintln!("  trace: {path} — open at https://ui.perfetto.dev")
                        }
                        Err(e) => eprintln!("  trace: could not write {path}: {e}"),
                    },
                    Err(e) => eprintln!("  trace: export failed: {e:#}"),
                }
            }
            eprintln!(
                "fuzz: {}/{} plan x arch cell(s) diverged on {}",
                out.failures.len(),
                out.plans as usize * out.archs.len(),
                out.kernel
            );
            diverged += out.failures.len();
        }
    }
    if uncaught > 0 {
        anyhow::bail!(
            "fuzz: {uncaught} semantic mutation(s) escaped the static linter \
             (see `lint-xval MISS` lines above)"
        )
    }
    if diverged > 0 {
        anyhow::bail!(
            "fuzz: {diverged}/{cells} plan x arch cell(s) diverged across {} kernel(s)",
            kernels.len()
        )
    }
    Ok(())
}

fn cmd_lint(args: &Args) -> anyhow::Result<()> {
    let kernel = args.get("kernel").unwrap_or("all");
    let seed = args.get_u64("seed", 2026);
    let archs = parse_archs(Some(args.get("arch").unwrap_or("sta,dae,spec")))?;
    let deny = crate::lint::Severity::parse(args.get("deny").unwrap_or("error"))
        .ok_or_else(|| anyhow::anyhow!("lint: --deny must be error|warn|info"))?;
    let show = if args.has_flag("verbose") {
        crate::lint::Severity::Info
    } else {
        crate::lint::Severity::Warn
    };
    let kernels: Vec<String> = if kernel == "all" {
        let mut ks: Vec<String> =
            crate::workloads::PAPER_KERNELS.iter().map(|s| s.to_string()).collect();
        ks.push("nested3".to_string());
        ks
    } else {
        vec![kernel.to_string()]
    };
    let mut denied = 0usize;
    for kernel in &kernels {
        let w = build_workload(kernel, seed, None)?;
        for &arch in &archs {
            let c = crate::transform::build(&w.module, 0, arch)?;
            let rep = crate::lint::lint_compiled(&w.module, 0, &c);
            let hits = rep.count_at_least(deny);
            denied += hits;
            let shown = rep.render(show.min(deny));
            if !shown.is_empty() {
                println!("---- {} / {} ----", kernel, arch.name());
                print!("{shown}");
            }
            if hits == 0 {
                println!(
                    "lint: {} / {} clean ({} note(s) below {} severity)",
                    kernel,
                    arch.name(),
                    rep.diags.len(),
                    deny.name()
                );
            }
        }
    }
    if denied > 0 {
        anyhow::bail!("lint: {denied} diagnostic(s) at or above {} severity", deny.name());
    }
    Ok(())
}

fn cmd_repro(args: &Args) -> anyhow::Result<()> {
    let seed = args.get_u64("seed", 2026);
    let what = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    match what {
        "table1" => report::table1(seed)?,
        "table2" => report::table2(seed)?,
        "fig2" => report::fig2(seed)?,
        "fig6" => report::fig6(seed)?,
        "fig7" => report::fig7(seed)?,
        "all" => {
            report::fig2(seed)?;
            report::table1(seed)?;
            report::fig6(seed)?;
            report::table2(seed)?;
            report::fig7(seed)?;
        }
        other => anyhow::bail!("unknown experiment {other}"),
    }
    Ok(())
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let kernel = args.get("kernel").unwrap_or("hist");
    let seed = args.get_u64("seed", 2026);
    let misspec = args.get("misspec").and_then(|s| s.parse().ok());
    let archs = parse_archs(args.get("arch"))?;
    let mut cfg = crate::sim::MachineConfig {
        trace: args.has_flag("trace"),
        ..Default::default()
    };
    apply_watchdog_knobs(&mut cfg, args);
    let row = runner::run_kernel(kernel, seed, misspec, &archs, &cfg, !args.has_flag("no-check"))?;
    report::print_row(&row);
    if cfg.trace {
        for (arch, tr) in &row.traces {
            println!("\n--- {} pipeline trace (first 50 cycles) ---", arch.name());
            println!("{}", tr.render(50));
        }
    }
    Ok(())
}

fn cmd_compile(args: &Args) -> anyhow::Result<()> {
    let kernel = args.get("kernel").unwrap_or("hist");
    let seed = args.get_u64("seed", 2026);
    let archs = parse_archs(args.get("arch"))?;
    let w = build_workload(kernel, seed, None)?;
    for arch in archs {
        let c = crate::transform::build(&w.module, 0, arch)?;
        println!("==== {} / {} ====", kernel, arch.name());
        match &c {
            crate::transform::Compiled::Monolithic { module, .. } => {
                print!("{}", crate::ir::printer::print_module(module));
            }
            crate::transform::Compiled::Dae { program, stats, .. } => {
                print!("{}", crate::ir::printer::print_module(&program.module));
                println!(
                    "// poison blocks: {}  calls: {}  merged: {}  refused: {:?}",
                    stats.poison_blocks, stats.poison_calls, stats.merged_blocks, stats.refused
                );
            }
        }
    }
    Ok(())
}

fn cmd_lsq_sweep(args: &Args) -> anyhow::Result<()> {
    let kernel = args.get("kernel").unwrap_or("bfs");
    let seed = args.get_u64("seed", 2026);
    let sizes: Vec<usize> = args
        .get("sizes")
        .unwrap_or("4,8,16,32,64")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    report::lsq_sweep(kernel, seed, &sizes)
}

pub(crate) fn parse_archs(s: Option<&str>) -> anyhow::Result<Vec<crate::transform::Arch>> {
    use crate::transform::Arch;
    match s {
        None | Some("all") => Ok(Arch::ALL.to_vec()),
        Some(s) => s
            .split(',')
            .map(|a| match a.trim().to_lowercase().as_str() {
                "sta" => Ok(Arch::Sta),
                "dae" => Ok(Arch::Dae),
                "spec" => Ok(Arch::Spec),
                "oracle" => Ok(Arch::Oracle),
                other => anyhow::bail!("unknown arch {other}"),
            })
            .collect(),
    }
}

/// Build a workload by name, supporting `nested<k>`.
pub fn build_workload(
    name: &str,
    seed: u64,
    misspec: Option<f64>,
) -> anyhow::Result<crate::workloads::Workload> {
    if let Some(k) = name.strip_prefix("nested") {
        let levels: usize = k.parse()?;
        return Ok(crate::workloads::nested::nested(levels, seed));
    }
    crate::workloads::build(name, seed, misspec)
}

#[cfg(test)]
mod tests {
    #[test]
    fn cli_list_and_help_run() {
        assert_eq!(super::cli_main(vec!["list".into()]), 0);
        assert_eq!(super::cli_main(vec![]), 0);
    }

    #[test]
    fn parse_archs_variants() {
        assert_eq!(super::parse_archs(None).unwrap().len(), 4);
        assert_eq!(super::parse_archs(Some("sta,spec")).unwrap().len(), 2);
        assert!(super::parse_archs(Some("bogus")).is_err());
    }
}
