//! Threaded experiment execution: one kernel × N architectures, with
//! functional cross-checks against the reference interpreter.

use crate::area::{estimate, AreaEstimate};
use crate::sim::machine::{simulate, SimResult};
use crate::sim::{interpret, memory_diff, MachineConfig};
use crate::transform::{build, Arch, Compiled};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;

/// One row of the paper's Table 1: a kernel across architectures.
pub struct ExperimentRow {
    pub kernel: String,
    pub cycles: HashMap<Arch, u64>,
    pub area: HashMap<Arch, AreaEstimate>,
    pub misspec_rate: f64,
    pub poison_blocks: usize,
    pub poison_calls: usize,
    pub refused: usize,
    pub traces: Vec<(Arch, crate::sim::Trace)>,
}

/// Compile + simulate `kernel` on every architecture in `archs`.
/// With `check`, assert the final memory matches the reference
/// interpreter (except ORACLE, which is expected to diverge).
pub fn run_kernel(
    kernel: &str,
    seed: u64,
    misspec: Option<f64>,
    archs: &[Arch],
    cfg: &MachineConfig,
    check: bool,
) -> Result<ExperimentRow> {
    let w = super::build_workload(kernel, seed, misspec)?;
    let reference = if check {
        Some(
            interpret(&w.module, &w.module.funcs[0], &w.args, w.memory.clone(), cfg.max_dyn_instrs)
                .with_context(|| format!("{kernel}: reference interpreter"))?,
        )
    } else {
        None
    };

    let mut row = ExperimentRow {
        kernel: kernel.to_string(),
        cycles: HashMap::new(),
        area: HashMap::new(),
        misspec_rate: 0.0,
        poison_blocks: 0,
        poison_calls: 0,
        refused: 0,
        traces: Vec::new(),
    };

    // architectures are independent — run them on scoped threads
    let results: Vec<(Arch, Result<(Compiled, SimResult)>)> = std::thread::scope(|s| {
        let handles: Vec<_> = archs
            .iter()
            .map(|&arch| {
                let w = &w;
                s.spawn(move || -> Result<(Compiled, SimResult)> {
                    let c = build(&w.module, 0, arch)
                        .with_context(|| format!("{kernel}/{}", arch.name()))?;
                    let sim = simulate(&c, &w.args, w.memory.clone(), cfg)
                        .with_context(|| format!("{kernel}/{}", arch.name()))?;
                    Ok((c, sim))
                })
            })
            .collect();
        archs
            .iter()
            .zip(handles)
            .map(|(&a, h)| (a, h.join().expect("sim thread panicked")))
            .collect()
    });

    for (arch, res) in results {
        let (c, mut sim) = res?;
        if let Some(r) = &reference {
            let ok = memory_diff(&sim.memory, &r.memory).is_none();
            if arch != Arch::Oracle && !ok {
                bail!(
                    "{kernel}/{}: final memory diverges from reference at {:?}",
                    arch.name(),
                    memory_diff(&sim.memory, &r.memory)
                );
            }
        }
        row.cycles.insert(arch, sim.cycles);
        row.area.insert(arch, estimate(&c, cfg));
        if arch == Arch::Spec {
            row.misspec_rate = sim.misspec_rate;
            if let Some(stats) = c.stats() {
                row.poison_blocks = stats.poison_blocks;
                row.poison_calls = stats.poison_calls;
                row.refused = stats.refused.len();
            }
        }
        if let Some(tr) = sim.trace.take() {
            row.traces.push((arch, tr));
        }
    }
    Ok(row)
}

/// Run a set of kernels in parallel (one thread per kernel).
pub fn run_suite(
    kernels: &[&str],
    seed: u64,
    archs: &[Arch],
    cfg: &MachineConfig,
) -> Result<Vec<ExperimentRow>> {
    std::thread::scope(|s| {
        let handles: Vec<_> = kernels
            .iter()
            .map(|&k| s.spawn(move || run_kernel(k, seed, None, archs, cfg, true)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("kernel thread panicked")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_hist_all_archs_checked() {
        let cfg = MachineConfig::default();
        let row = run_kernel("hist", 1, None, &Arch::ALL, &cfg, true).unwrap();
        assert_eq!(row.cycles.len(), 4);
        assert!(row.poison_calls >= 1);
        assert!(row.cycles[&Arch::Spec] < row.cycles[&Arch::Sta]);
    }

    #[test]
    fn suite_runs_in_parallel() {
        let cfg = MachineConfig::default();
        let rows = run_suite(&["hist", "thr"], 1, &[Arch::Sta, Arch::Spec], &cfg).unwrap();
        assert_eq!(rows.len(), 2);
    }
}
