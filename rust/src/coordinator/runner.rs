//! Threaded experiment execution: one kernel × N architectures, with
//! functional cross-checks against the reference interpreter.
//!
//! Panic-safe by construction: every worker thread body runs under
//! `catch_unwind`, so a panic in one kernel × arch cell becomes a
//! captured error naming the cell instead of aborting the whole suite
//! (`run_suite` returns the completed rows plus per-kernel failures).

use crate::area::{estimate, AreaEstimate};
use crate::sim::machine::SimResult;
use crate::sim::{interpret, memory_diff, MachineConfig, SimSession};
use crate::transform::{build, Arch, Compiled};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// One row of the paper's Table 1: a kernel across architectures.
pub struct ExperimentRow {
    pub kernel: String,
    pub cycles: HashMap<Arch, u64>,
    pub area: HashMap<Arch, AreaEstimate>,
    pub misspec_rate: f64,
    pub poison_blocks: usize,
    pub poison_calls: usize,
    pub refused: usize,
    pub traces: Vec<(Arch, crate::sim::Trace)>,
}

/// A kernel whose row could not be completed, with the error naming the
/// kernel (and, for per-arch failures, the architecture).
pub struct SuiteFailure {
    pub kernel: String,
    pub error: anyhow::Error,
}

/// Partial-tolerant suite result: completed rows in kernel order, plus
/// the cells that failed (panic, stall, divergence) and why.
pub struct SuiteOutcome {
    pub rows: Vec<ExperimentRow>,
    pub failures: Vec<SuiteFailure>,
}

/// Render a `catch_unwind` payload as a message.
fn panic_msg(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Test hook: lets the suite-resilience unit test inject a panic without
/// a poisoned workload. Inert outside `cfg(test)`.
#[cfg(test)]
fn test_panic_hook(kernel: &str) {
    if kernel == "__panic" {
        panic!("injected test panic in kernel thread");
    }
}

#[cfg(not(test))]
fn test_panic_hook(_kernel: &str) {}

/// Compile + simulate `kernel` on every architecture in `archs`.
/// With `check`, assert the final memory matches the reference
/// interpreter (except ORACLE, which is expected to diverge).
pub fn run_kernel(
    kernel: &str,
    seed: u64,
    misspec: Option<f64>,
    archs: &[Arch],
    cfg: &MachineConfig,
    check: bool,
) -> Result<ExperimentRow> {
    test_panic_hook(kernel);
    let w = super::build_workload(kernel, seed, misspec)?;
    let reference = if check {
        Some(
            interpret(&w.module, &w.module.funcs[0], &w.args, w.memory.clone(), cfg.max_dyn_instrs)
                .with_context(|| format!("{kernel}: reference interpreter"))?,
        )
    } else {
        None
    };

    let mut row = ExperimentRow {
        kernel: kernel.to_string(),
        cycles: HashMap::new(),
        area: HashMap::new(),
        misspec_rate: 0.0,
        poison_blocks: 0,
        poison_calls: 0,
        refused: 0,
        traces: Vec::new(),
    };

    // architectures are independent — run them on scoped threads; a
    // panicking arch is captured and reported as that cell's error
    let results: Vec<(Arch, Result<(Compiled, SimResult)>)> = std::thread::scope(|s| {
        let handles: Vec<_> = archs
            .iter()
            .map(|&arch| {
                let w = &w;
                s.spawn(move || {
                    catch_unwind(AssertUnwindSafe(|| -> Result<(Compiled, SimResult)> {
                        let c = build(&w.module, 0, arch)
                            .with_context(|| format!("{kernel}/{}", arch.name()))?;
                        // explicit session (what `simulate` wraps): the
                        // borrow of `c` ends at into_result, so `c` can
                        // move out alongside the result
                        let sim = (|| -> Result<SimResult> {
                            let mut s = SimSession::new(&c, cfg, w.memory.clone())?;
                            s.run(&w.args)?;
                            Ok(s.into_result())
                        })()
                        .with_context(|| format!("{kernel}/{}", arch.name()))?;
                        Ok((c, sim))
                    }))
                })
            })
            .collect();
        archs
            .iter()
            .zip(handles)
            .map(|(&a, h)| {
                // join() wraps the catch_unwind result: the outer Err is
                // unreachable (the closure never unwinds past the catch)
                // but folds into the same panic arm for safety.
                let res = match h.join() {
                    Ok(Ok(r)) => r,
                    Ok(Err(payload)) | Err(payload) => Err(anyhow!(
                        "{kernel}/{}: simulation thread panicked: {}",
                        a.name(),
                        panic_msg(payload.as_ref())
                    )),
                };
                (a, res)
            })
            .collect()
    });

    for (arch, res) in results {
        let (c, mut sim) = res?;
        if let Some(r) = &reference {
            let ok = memory_diff(&sim.memory, &r.memory).is_none();
            if arch != Arch::Oracle && !ok {
                bail!(
                    "{kernel}/{}: final memory diverges from reference at {:?}",
                    arch.name(),
                    memory_diff(&sim.memory, &r.memory)
                );
            }
        }
        row.cycles.insert(arch, sim.cycles);
        row.area.insert(arch, estimate(&c, cfg));
        if arch == Arch::Spec {
            row.misspec_rate = sim.misspec_rate;
            if let Some(stats) = c.stats() {
                row.poison_blocks = stats.poison_blocks;
                row.poison_calls = stats.poison_calls;
                row.refused = stats.refused.len();
            }
        }
        if let Some(tr) = sim.trace.take() {
            row.traces.push((arch, tr));
        }
    }
    Ok(row)
}

/// Run a set of kernels in parallel (one thread per kernel). Never
/// fails as a whole: kernels that error or panic are reported in
/// `SuiteOutcome::failures` naming the kernel × arch cell, and the
/// remaining rows are returned in kernel order.
pub fn run_suite(
    kernels: &[&str],
    seed: u64,
    archs: &[Arch],
    cfg: &MachineConfig,
) -> SuiteOutcome {
    let results: Vec<(String, Result<ExperimentRow>)> = std::thread::scope(|s| {
        let handles: Vec<_> = kernels
            .iter()
            .map(|&k| {
                s.spawn(move || {
                    catch_unwind(AssertUnwindSafe(|| run_kernel(k, seed, None, archs, cfg, true)))
                })
            })
            .collect();
        kernels
            .iter()
            .zip(handles)
            .map(|(&k, h)| {
                let res = match h.join() {
                    Ok(Ok(row)) => row,
                    Ok(Err(payload)) | Err(payload) => Err(anyhow!(
                        "{k}: kernel thread panicked: {}",
                        panic_msg(payload.as_ref())
                    )),
                };
                (k.to_string(), res)
            })
            .collect()
    });

    let mut out = SuiteOutcome { rows: Vec::new(), failures: Vec::new() };
    for (kernel, res) in results {
        match res {
            Ok(row) => out.rows.push(row),
            Err(error) => out.failures.push(SuiteFailure { kernel, error }),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_hist_all_archs_checked() {
        let cfg = MachineConfig::default();
        let row = run_kernel("hist", 1, None, &Arch::ALL, &cfg, true).unwrap();
        assert_eq!(row.cycles.len(), 4);
        assert!(row.poison_calls >= 1);
        assert!(row.cycles[&Arch::Spec] < row.cycles[&Arch::Sta]);
    }

    #[test]
    fn suite_runs_in_parallel() {
        let cfg = MachineConfig::default();
        let out = run_suite(&["hist", "thr"], 1, &[Arch::Sta, Arch::Spec], &cfg);
        assert_eq!(out.rows.len(), 2);
        assert!(out.failures.is_empty());
    }

    #[test]
    fn suite_partial_on_panic() {
        let cfg = MachineConfig::default();
        let out = run_suite(&["hist", "__panic", "thr"], 1, &[Arch::Sta, Arch::Spec], &cfg);
        let kernels: Vec<&str> = out.rows.iter().map(|r| r.kernel.as_str()).collect();
        assert_eq!(kernels, ["hist", "thr"], "good kernels still complete");
        assert_eq!(out.failures.len(), 1);
        assert_eq!(out.failures[0].kernel, "__panic");
        let msg = format!("{:#}", out.failures[0].error);
        assert!(msg.contains("panicked"), "failure names the panic: {msg}");
        assert!(msg.contains("__panic"), "failure names the kernel: {msg}");
    }

    #[test]
    fn unknown_kernel_is_captured_not_fatal() {
        let cfg = MachineConfig::default();
        let out = run_suite(&["no_such_kernel"], 1, &[Arch::Sta], &cfg);
        assert!(out.rows.is_empty());
        assert_eq!(out.failures.len(), 1);
        assert_eq!(out.failures[0].kernel, "no_such_kernel");
    }
}
