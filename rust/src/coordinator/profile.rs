//! `dae-spec profile` — run one kernel across architectures with the
//! metrics layer and pipeline tracing enabled, then report the
//! telemetry ([`crate::metrics`]): per-unit cycle accounting, channel
//! occupancy, LSQ residency, decoupling slack, MLP and speculation
//! counters.
//!
//! Three output forms:
//!
//! - default: the human-readable [`MetricsSummary::render`] report per
//!   architecture;
//! - `--json` (and/or `--out FILE`): the machine-readable schema
//!   `dae-spec-profile/v1` — deterministic, same seed → byte-identical
//!   document (pinned by `rust/tests/metrics.rs`);
//! - `--perfetto BASE.json`: one Chrome/Perfetto `trace_event`
//!   document per architecture, written to `BASE.<arch>.json` — open
//!   at <https://ui.perfetto.dev>.

use crate::metrics::MetricsSummary;
use crate::sim::{MachineConfig, SimSession};
use crate::transform::{build, Arch};
use crate::util::{Args, Json};
use anyhow::{Context, Result};

/// One profiled kernel × arch cell.
pub struct ProfileRun {
    pub arch: Arch,
    pub cycles: u64,
    pub summary: MetricsSummary,
    /// Chrome/Perfetto `trace_event` document of the run.
    pub perfetto: Json,
}

/// Profile one cell: compile, run once with metrics + trace forced on
/// (profiling observes the machine; it never changes its timing — the
/// run's cycles equal a metrics-off run's, pinned by
/// `rust/tests/metrics.rs`).
pub fn profile_kernel(
    kernel: &str,
    seed: u64,
    misspec: Option<f64>,
    arch: Arch,
    cfg: &MachineConfig,
) -> Result<ProfileRun> {
    let mut pcfg = cfg.clone();
    pcfg.metrics = true;
    pcfg.trace = true;
    let w = super::build_workload(kernel, seed, misspec)
        .with_context(|| format!("profile: building workload {kernel}"))?;
    let c = build(&w.module, 0, arch)
        .with_context(|| format!("profile: compiling {kernel}/{}", arch.name()))?;
    let mut sess = SimSession::new(&c, &pcfg, w.memory.clone())?;
    let stats = sess
        .run(&w.args)
        .with_context(|| format!("profile: {kernel}/{}", arch.name()))?;
    let summary = sess
        .metrics_summary()
        .cloned()
        .expect("metrics are forced on for profiling runs");
    let label = format!("{kernel}/{} seed={seed}", arch.name());
    let perfetto = sess.perfetto(&label).expect("trace is forced on for profiling runs");
    Ok(ProfileRun { arch, cycles: stats.cycles, summary, perfetto })
}

/// The `dae-spec-profile/v1` document for a set of profiled cells.
pub fn profile_doc(kernel: &str, seed: u64, runs: &[ProfileRun]) -> Json {
    let results = runs
        .iter()
        .map(|r| {
            Json::Obj(vec![
                ("arch".into(), Json::Str(r.arch.name().into())),
                ("metrics".into(), r.summary.to_json()),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("schema".into(), Json::Str("dae-spec-profile/v1".into())),
        ("kernel".into(), Json::Str(kernel.into())),
        ("seed".into(), Json::Num(seed as f64)),
        ("results".into(), Json::Arr(results)),
    ])
}

/// Convenience: profile `kernel` across `archs` and fold into the
/// `dae-spec-profile/v1` document (what `--json` prints).
pub fn profile_json(
    kernel: &str,
    seed: u64,
    misspec: Option<f64>,
    archs: &[Arch],
    cfg: &MachineConfig,
) -> Result<Json> {
    let runs: Vec<ProfileRun> = archs
        .iter()
        .map(|&a| profile_kernel(kernel, seed, misspec, a, cfg))
        .collect::<Result<_>>()?;
    Ok(profile_doc(kernel, seed, &runs))
}

/// `BASE.json` + `DAE` → `BASE.dae.json` (arch inserted before the
/// extension so the per-arch traces sort next to each other).
fn perfetto_path(base: &str, arch: &str) -> String {
    let arch = arch.to_lowercase();
    match base.strip_suffix(".json") {
        Some(stem) => format!("{stem}.{arch}.json"),
        None => format!("{base}.{arch}.json"),
    }
}

pub fn cmd_profile(args: &Args) -> Result<()> {
    let kernel = args.get("kernel").unwrap_or("hist");
    let seed = args.get_u64("seed", 2026);
    let misspec = args.get("misspec").and_then(|s| s.parse().ok());
    let archs = super::parse_archs(Some(args.get("arch").unwrap_or("sta,dae,spec")))?;
    let mut cfg = MachineConfig::default();
    super::apply_watchdog_knobs(&mut cfg, args);

    let runs: Vec<ProfileRun> = archs
        .iter()
        .map(|&a| profile_kernel(kernel, seed, misspec, a, &cfg))
        .collect::<Result<_>>()?;

    if let Some(base) = args.get("perfetto") {
        for r in &runs {
            let path = perfetto_path(base, r.arch.name());
            std::fs::write(&path, r.perfetto.render())
                .with_context(|| format!("profile: writing {path}"))?;
            println!("wrote {path} — open at https://ui.perfetto.dev");
        }
    }

    let want_json = args.has_flag("json");
    let out = args.get("out");
    if want_json || out.is_some() {
        let text = profile_doc(kernel, seed, &runs).render();
        if let Some(path) = out {
            std::fs::write(path, &text).with_context(|| format!("profile: writing {path}"))?;
            println!("wrote {path}");
        }
        if want_json {
            print!("{text}");
        }
    } else {
        for r in &runs {
            println!("==== {} / {} ====", kernel, r.arch.name());
            print!("{}", r.summary.render());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn perfetto_path_inserts_arch_before_extension() {
        assert_eq!(super::perfetto_path("trace.json", "SPEC"), "trace.spec.json");
        assert_eq!(super::perfetto_path("trace", "DAE"), "trace.dae.json");
    }
}
