//! Paper-format reports: Table 1, Table 2, Fig. 2, Fig. 6, Fig. 7 and
//! the §8.2.1 LSQ-pressure ablation. Each prints the same rows/series
//! the paper reports (absolute numbers differ — our substrate is a
//! simulator, see DESIGN.md — the *shapes* are the reproduction target).

use super::runner::{run_kernel, run_suite, ExperimentRow, SuiteOutcome};
use crate::sim::{MachineConfig, StallDiagnostic};
use crate::transform::Arch;
use crate::workloads::PAPER_KERNELS;
use anyhow::{bail, Result};

/// If `err`'s root cause is a [`StallDiagnostic`], print its full
/// multi-line machine-state report (channel occupancies, LSQ fill,
/// per-unit t_ctrl) to stderr. Returns whether one was found.
pub fn print_stall(err: &anyhow::Error) -> bool {
    match err.downcast_ref::<StallDiagnostic>() {
        Some(diag) => {
            eprint!("{}", diag.render());
            true
        }
        None => false,
    }
}

/// Report the failed kernel × arch cells of a partial suite run.
pub fn print_suite_failures(out: &SuiteOutcome) {
    for f in &out.failures {
        eprintln!("suite: kernel {} failed: {:#}", f.kernel, f.error);
        print_stall(&f.error);
    }
}

/// Unwrap a suite outcome for reports that need every kernel: print
/// what failed, bail only when nothing completed at all.
fn suite_rows(out: SuiteOutcome) -> Result<Vec<ExperimentRow>> {
    print_suite_failures(&out);
    if out.rows.is_empty() {
        bail!("suite produced no rows ({} kernel(s) failed)", out.failures.len());
    }
    Ok(out.rows)
}

pub fn print_row(row: &ExperimentRow) {
    println!(
        "{:<8} cycles: STA={} DAE={} SPEC={} ORACLE={}  misspec={:.0}%  poison blocks/calls: {}/{}",
        row.kernel,
        row.cycles.get(&Arch::Sta).copied().unwrap_or(0),
        row.cycles.get(&Arch::Dae).copied().unwrap_or(0),
        row.cycles.get(&Arch::Spec).copied().unwrap_or(0),
        row.cycles.get(&Arch::Oracle).copied().unwrap_or(0),
        row.misspec_rate * 100.0,
        row.poison_blocks,
        row.poison_calls,
    );
}

fn harmonic_mean(xs: &[f64]) -> f64 {
    xs.len() as f64 / xs.iter().map(|x| 1.0 / x).sum::<f64>()
}

/// Table 1: poison blocks/calls, mis-speculation rate, absolute cycles
/// and area for STA / DAE / SPEC / ORACLE across the nine kernels.
pub fn table1(seed: u64) -> Result<()> {
    let cfg = MachineConfig::default();
    let rows = suite_rows(run_suite(&PAPER_KERNELS, seed, &Arch::ALL, &cfg))?;

    println!("\n== Table 1: absolute performance and area (cf. paper Table 1) ==");
    println!(
        "{:<7}|{:>7}{:>7}{:>10}|{:>9}{:>9}{:>9}{:>9}|{:>8}{:>8}{:>8}{:>8}",
        "Kernel", "Poison", "Calls", "Mis-spec",
        "STA", "DAE", "SPEC", "ORACLE",
        "STA", "DAE", "SPEC", "ORACLE"
    );
    println!(
        "{:<7}|{:>7}{:>7}{:>10}|{:>36}|{:>32}",
        "", "Blocks", "", "Rate", "Cycles", "Area (ALM-equiv)"
    );
    let mut rel_cycles: Vec<[f64; 3]> = Vec::new();
    let mut rel_area: Vec<[f64; 3]> = Vec::new();
    for row in &rows {
        println!(
            "{:<7}|{:>7}{:>7}{:>9.0}%|{:>9}{:>9}{:>9}{:>9}|{:>8}{:>8}{:>8}{:>8}",
            row.kernel,
            row.poison_blocks,
            row.poison_calls,
            row.misspec_rate * 100.0,
            row.cycles[&Arch::Sta],
            row.cycles[&Arch::Dae],
            row.cycles[&Arch::Spec],
            row.cycles[&Arch::Oracle],
            row.area[&Arch::Sta].total,
            row.area[&Arch::Dae].total,
            row.area[&Arch::Spec].total,
            row.area[&Arch::Oracle].total,
        );
        let sta_c = row.cycles[&Arch::Sta] as f64;
        rel_cycles.push([
            row.cycles[&Arch::Dae] as f64 / sta_c,
            row.cycles[&Arch::Spec] as f64 / sta_c,
            row.cycles[&Arch::Oracle] as f64 / sta_c,
        ]);
        let sta_a = row.area[&Arch::Sta].total as f64;
        rel_area.push([
            row.area[&Arch::Dae].total as f64 / sta_a,
            row.area[&Arch::Spec].total as f64 / sta_a,
            row.area[&Arch::Oracle].total as f64 / sta_a,
        ]);
    }
    let hm = |i: usize, xs: &[[f64; 3]]| harmonic_mean(&xs.iter().map(|r| r[i]).collect::<Vec<_>>());
    println!(
        "{:<7}|{:>24}|{:>9}{:>9.2}{:>9.2}{:>9.2}|{:>8}{:>8.2}{:>8.2}{:>8.2}",
        "HMean", "(cycles / area vs STA)",
        1, hm(0, &rel_cycles), hm(1, &rel_cycles), hm(2, &rel_cycles),
        1, hm(0, &rel_area), hm(1, &rel_area), hm(2, &rel_area),
    );
    println!(
        "(paper Table 1 harmonic means: cycles 1 / 3.2 / 0.51 / 0.48; area 1 / 1.16 / 1.42 / 1.36)"
    );
    Ok(())
}

/// Fig. 6: speedup of DAE / SPEC / ORACLE normalised to STA.
pub fn fig6(seed: u64) -> Result<()> {
    let cfg = MachineConfig::default();
    let rows = suite_rows(run_suite(&PAPER_KERNELS, seed, &Arch::ALL, &cfg))?;
    println!("\n== Figure 6: speedup over STA (higher is better; paper: SPEC avg 1.9x, up to 3x) ==");
    println!("{:<8}{:>8}{:>8}{:>8}", "kernel", "DAE", "SPEC", "ORACLE");
    let mut speedups: Vec<[f64; 3]> = Vec::new();
    for row in &rows {
        let sta = row.cycles[&Arch::Sta] as f64;
        let s = [
            sta / row.cycles[&Arch::Dae] as f64,
            sta / row.cycles[&Arch::Spec] as f64,
            sta / row.cycles[&Arch::Oracle] as f64,
        ];
        println!("{:<8}{:>8.2}{:>8.2}{:>8.2}", row.kernel, s[0], s[1], s[2]);
        // bar chart for the SPEC column
        let bar = "#".repeat((s[1] * 10.0).round() as usize);
        println!("        SPEC |{bar}");
        speedups.push(s);
    }
    let hm = |i: usize| {
        harmonic_mean(&speedups.iter().map(|r| r[i]).collect::<Vec<_>>())
    };
    println!("{:<8}{:>8.2}{:>8.2}{:>8.2}   (harmonic mean)", "HMean", hm(0), hm(1), hm(2));
    Ok(())
}

/// Table 2: SPEC cycle counts as the mis-speculation rate changes
/// (paper: hist/thr/mm at 0..100% — no correlation ⇒ no mis-spec cost).
pub fn table2(seed: u64) -> Result<()> {
    let cfg = MachineConfig::default();
    let rates = [0.0, 0.2, 0.4, 0.6, 0.8, 1.0];
    println!("\n== Table 2: SPEC cycles vs mis-speculation rate (cf. paper Table 2) ==");
    print!("{:<8}", "Kernel");
    for r in rates {
        print!("{:>8.0}%", r * 100.0);
    }
    println!("{:>8}", "sigma");
    for kernel in ["hist", "thr", "mm"] {
        let mut cycles = Vec::new();
        for rate in rates {
            let row = run_kernel(kernel, seed, Some(rate), &[Arch::Spec], &cfg, true)?;
            cycles.push(row.cycles[&Arch::Spec]);
        }
        let mean = cycles.iter().sum::<u64>() as f64 / cycles.len() as f64;
        let var = cycles.iter().map(|&c| (c as f64 - mean).powi(2)).sum::<f64>()
            / cycles.len() as f64;
        print!("{kernel:<8}");
        for c in &cycles {
            print!("{c:>9}");
        }
        println!("{:>8.0}", var.sqrt());
    }
    println!("(paper: sigma 21 on thr, 18 on mm — rate does not correlate with cycles)");
    Ok(())
}

/// Fig. 7: area + performance overhead of SPEC over ORACLE as the number
/// of poison blocks grows (nested-if template, 1..8 levels).
pub fn fig7(seed: u64) -> Result<()> {
    let cfg = MachineConfig::default();
    println!("\n== Figure 7: SPEC overhead over ORACLE vs poison blocks (nested template) ==");
    println!(
        "{:<8}{:>8}{:>8}{:>11}{:>11}{:>11}{:>12}",
        "levels", "blocks", "calls", "cyc SPEC", "cyc ORACLE", "perf ovh", "CU area ovh"
    );
    for levels in 1..=8 {
        let kernel = format!("nested{levels}");
        let row = run_kernel(&kernel, seed, None, &[Arch::Spec, Arch::Oracle], &cfg, true)?;
        let perf = row.cycles[&Arch::Spec] as f64 / row.cycles[&Arch::Oracle] as f64 - 1.0;
        let area = row.area[&Arch::Spec].cu as f64 / row.area[&Arch::Oracle].cu as f64 - 1.0;
        println!(
            "{:<8}{:>8}{:>8}{:>11}{:>11}{:>10.1}%{:>11.1}%",
            levels,
            row.poison_blocks,
            row.poison_calls,
            row.cycles[&Arch::Spec],
            row.cycles[&Arch::Oracle],
            perf * 100.0,
            area * 100.0,
        );
    }
    println!("(paper: perf overhead ~0%, CU area grows <5% per poison block, <25% at 8 levels)");
    Ok(())
}

/// Fig. 2: pipeline timelines of decoupled (SPEC) vs non-decoupled (DAE)
/// address generation on the running example.
pub fn fig2(seed: u64) -> Result<()> {
    let cfg = MachineConfig { trace: true, ..Default::default() };
    println!("\n== Figure 2: decoupled vs non-decoupled address generation (hist kernel) ==");
    let row = run_kernel("hist", seed, None, &[Arch::Dae, Arch::Spec], &cfg, true)?;
    for (arch, tr) in &row.traces {
        let label = match arch {
            Arch::Spec => "decoupled (SPEC — store addr speculated, AGU runs ahead)",
            Arch::Dae => "non-decoupled (DAE — AGU waits for load values)",
            _ => arch.name(),
        };
        println!("\n--- {label} ---");
        println!("{}", tr.render(60));
    }
    println!(
        "(cf. paper Fig. 2: the non-decoupled AGU's store address arrives late,\n stalling the RAW check for the next load and lowering load throughput)"
    );
    Ok(())
}

/// §8.2.1 ablation: store-queue size sensitivity on deep-pipeline,
/// high-mis-speculation kernels.
pub fn lsq_sweep(kernel: &str, seed: u64, sizes: &[usize]) -> Result<()> {
    println!("\n== LSQ store-queue sweep on {kernel} (cf. paper §8.2.1) ==");
    println!("{:<10}{:>12}{:>12}", "st_q", "SPEC cycles", "misspec");
    for &st_q in sizes {
        let cfg = MachineConfig { st_q, ..Default::default() };
        let row = run_kernel(kernel, seed, None, &[Arch::Spec], &cfg, true)?;
        println!(
            "{:<10}{:>12}{:>11.0}%",
            st_q,
            row.cycles[&Arch::Spec],
            row.misspec_rate * 100.0
        );
    }
    Ok(())
}
