//! `dae_spec` — compiler support for speculation in Decoupled Access/Execute
//! (DAE) architectures, a full reproduction of Szafarczyk et al., CC '25.
//!
//! The crate is organised as a classic compiler + machine-model stack:
//!
//! - [`ir`] — a small SSA intermediate representation with array-based
//!   memory operations and DAE channel intrinsics (`send_ld_addr`,
//!   `send_st_addr`, `consume_val`, `produce_val`, `poison`).
//! - [`analysis`] — dominators, post-dominators, control dependence, loop
//!   info, reachability, def-use chains, and the paper's
//!   loss-of-decoupling (LoD) analysis (§4).
//! - [`transform`] — the decoupling transformation (§3.2) and the paper's
//!   core contribution: Algorithm 1 (speculative hoisting in the AGU),
//!   Algorithms 2 + 3 (poison placement in the CU), poison-block merging
//!   (§5.3) and speculative load consumption (§5.4).
//! - [`sim`] — a cycle-level timing model of the DAE machine (AGU/DU/CU,
//!   FIFOs, dual-ported SRAM, load-store queue) plus a statically
//!   scheduled (STA) baseline and a functional interpreter.
//! - [`area`] — an analytical ALM area model standing in for Quartus.
//! - [`workloads`] — the nine paper benchmarks, data generators, and the
//!   Fig. 7 nested-if template.
//! - [`coordinator`] — experiment orchestration: configs, threaded runs
//!   (panic-safe, partial-suite tolerant), paper-format reports.
//! - [`fault`] — deterministic fault injection (latency spikes, channel
//!   jitter, LSQ squeezes, mis-speculation storms) and the `fuzz`
//!   differential harness asserting bit-exact equivalence against the
//!   reference interpreter.
//! - `runtime` — PJRT-backed execution of AOT-compiled JAX/Pallas
//!   artifacts and the vectorised speculation engine (paper §10 future
//!   work); gated behind the `pjrt` feature so the default build has no
//!   XLA dependency.
//! - [`lint`] — the static semantic verifier (`dae-spec lint`): after
//!   every `transform::build` it checks decoupling legality (DEC),
//!   channel push/pop balance per path and per loop iteration (CHAN),
//!   poison coverage + speculative-value taint (POISON), and
//!   store-order/sequential-consistency preservation (SC) — the static
//!   shadow of the paper's Lemma 6.1 and Theorem 6.2. Runs automatically
//!   in debug builds the way `ir::verify` does; the fuzz harness
//!   cross-validates that every injectable semantic mutation (dropped
//!   poison, dropped push, dropped produce) is flagged statically.
//! - [`metrics`] — the observability layer: always-available,
//!   zero-cost-when-off telemetry collected inside the simulator (see
//!   the *Observability* section below).
//! - [`util`] — PRNG, mini CLI, bench + property-test harnesses (the
//!   offline build has no clap/criterion/proptest).
//!
//! # Static verification
//!
//! `ir::verify` rejects structurally malformed SSA (including
//! irreducible CFGs — every retreating edge must be a backedge to a
//! dominating header); [`lint`] rejects semantically broken *decoupled*
//! modules. Diagnostics are structured (`rule[severity] @function block:
//! message` plus the offending instruction rendered by `ir::printer`);
//! `dae-spec lint --kernel all` sweeps every paper kernel across
//! STA/DAE/SPEC and exits non-zero on `--deny`-level findings. Info
//! notes (LoD-chain attribution, skipped path budgets) are expected on
//! healthy builds and never fail the lint; errors mean the module must
//! not be simulated.
//!
//! # Performance
//!
//! The simulator hot path is interpreter-free by construction:
//!
//! - **Pre-decoded IR** ([`sim::decoded`]): `transform::build` lowers
//!   each function once into a flat [`sim::decoded::DecodedFn`] —
//!   contiguous instruction stream with operand value-slots resolved to
//!   indices, branch targets as block indices, and per-(predecessor,
//!   block) φ-assignment tables — carried on
//!   [`transform::Compiled`], so `simulate` never touches the IR.
//! - **Dense channel ids**: every DAE channel is interned to a `u32` at
//!   decode time ([`sim::decoded::ChanTable`]); the machine's channel
//!   state and per-mem statistics are plain vectors, with no hash-map
//!   lookups per push/pop.
//! - **Wake-list scheduler**: blocked units and LSQs register the
//!   channel event they wait on (push or pop); each scheduler round
//!   steps only woken entities, in a fixed deterministic order, so idle
//!   polling disappears while cycles, memory and commit order stay
//!   bit-identical (pinned by the `determinism` integration test and
//!   the fault-fuzz differential harness).
//! - **Reusable sessions** ([`sim::SimSession`]): repeated-run
//!   consumers allocate the machine once per `(Compiled,
//!   MachineConfig)` and re-run it with zero steady-state heap
//!   allocation — every buffer (register files, channel FIFOs, LSQ
//!   rings/ROBs, stats, commit log) is reset in place and memory is
//!   restored from an immutable `MemorySnapshot` by memcpy.
//!   [`sim::simulate`] is the one-shot wrapper. A session pins the
//!   compiled program and machine shape; arguments and the fault plan
//!   (`set_fault`) may vary per run, and a failed run never leaks
//!   state into the next (reset happens on entry). Re-runs are
//!   bit-identical to fresh calls — same determinism pins as above.
//! - **Parallel harnesses** ([`util::pool`]): `dae-spec fuzz --jobs N`
//!   fans the kernel × plan × arch grid over a bounded panic-safe
//!   worker pool with deterministic, job-count-independent results;
//!   `dae-spec bench` parallelizes compile+validate the same way while
//!   keeping the timing loop serial by default (`--time-jobs` opts in,
//!   with a documented contention caveat).
//!
//! Measure with `dae-spec bench` (writes `BENCH_sim.json`, schema
//! `dae-spec-bench/v3` with mean/min/median plus a metrics summary per
//! cell); compare against a saved run with
//! `dae-spec bench --baseline BENCH_sim.json --max-regress 10`, which
//! fails if any kernel × arch cell's best time regresses by more than
//! the given percentage, or rewrite the committed baseline from fresh
//! measurements with `--refresh-baseline` (the reader accepts schemas
//! v1–v3).
//!
//! # Observability
//!
//! `MachineConfig::metrics` turns on the [`metrics`] layer: telemetry
//! collected inside the simulator that observes the timestamp-dataflow
//! machine without perturbing it — cycles, memory and commit logs stay
//! bit-identical with metrics on or off, on every kernel × arch
//! (pinned by `rust/tests/metrics.rs`), and the collected numbers are
//! deterministic (same seed → byte-identical `profile --json`). What
//! is collected:
//!
//! - **Per-unit cycle accounting** — busy (dynamic instructions),
//!   blocked-on-pop cycles attributed per channel (how long the AGU or
//!   CU idled waiting for each FIFO), blocked-on-push events (full
//!   FIFOs parking a producer) and an idle estimate.
//! - **Per-channel occupancy** — high-water marks, log2-bucketed
//!   occupancy histograms, push/pop/poison counts.
//! - **LSQ fill and residency** — admissions, window high-water mark,
//!   mean residency, and the cycles of mis-speculated store residency
//!   discarded by poisons.
//! - **Speculation counters** — speculated store/load requests issued,
//!   poisons, poison rate, total and per array.
//! - **Decoupling slack** — the paper-level derived metric: the AGU's
//!   lead over the CU, measured at every Lemma 6.1 store pairing as
//!   `t(value arrival) − t(request arrival)` cycles, plus the
//!   in-flight request count at that moment (min/mean/max and sampled
//!   tracks per array). Positive slack *is* decoupling; DAE's LoD
//!   synchronisation collapses it, SPEC's speculation restores it.
//! - **MLP** — mean outstanding loads (Σ load latency / cycles).
//!
//! Surfaces: `dae-spec profile --kernel K --arch sta,dae,spec`
//! (human-readable report; `--json` for the machine-readable schema
//! `dae-spec-profile/v1`; `--out FILE` to write it), per-cell
//! `metrics` objects in `BENCH_sim.json`, metrics snapshots inside
//! stall diagnostics, and Chrome/Perfetto trace export:
//! `dae-spec profile --perfetto BASE.json` writes one
//! `BASE.<arch>.json` trace-event document per architecture — open it
//! at <https://ui.perfetto.dev> to see unit lanes, poison instants and
//! occupancy/slack counter tracks. `dae-spec fuzz` dumps the same
//! document for every minimized failing plan next to its replay seed.

pub mod analysis;
pub mod area;
pub mod coordinator;
pub mod fault;
pub mod ir;
pub mod lint;
pub mod metrics;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sim;
pub mod transform;
pub mod util;
pub mod workloads;
