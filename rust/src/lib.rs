//! `dae_spec` — compiler support for speculation in Decoupled Access/Execute
//! (DAE) architectures, a full reproduction of Szafarczyk et al., CC '25.
//!
//! The crate is organised as a classic compiler + machine-model stack:
//!
//! - [`ir`] — a small SSA intermediate representation with array-based
//!   memory operations and DAE channel intrinsics (`send_ld_addr`,
//!   `send_st_addr`, `consume_val`, `produce_val`, `poison`).
//! - [`analysis`] — dominators, post-dominators, control dependence, loop
//!   info, reachability, def-use chains, and the paper's
//!   loss-of-decoupling (LoD) analysis (§4).
//! - [`transform`] — the decoupling transformation (§3.2) and the paper's
//!   core contribution: Algorithm 1 (speculative hoisting in the AGU),
//!   Algorithms 2 + 3 (poison placement in the CU), poison-block merging
//!   (§5.3) and speculative load consumption (§5.4).
//! - [`sim`] — a cycle-level timing model of the DAE machine (AGU/DU/CU,
//!   FIFOs, dual-ported SRAM, load-store queue) plus a statically
//!   scheduled (STA) baseline and a functional interpreter.
//! - [`area`] — an analytical ALM area model standing in for Quartus.
//! - [`workloads`] — the nine paper benchmarks, data generators, and the
//!   Fig. 7 nested-if template.
//! - [`coordinator`] — experiment orchestration: configs, threaded runs
//!   (panic-safe, partial-suite tolerant), paper-format reports.
//! - [`fault`] — deterministic fault injection (latency spikes, channel
//!   jitter, LSQ squeezes, mis-speculation storms) and the `fuzz`
//!   differential harness asserting bit-exact equivalence against the
//!   reference interpreter.
//! - `runtime` — PJRT-backed execution of AOT-compiled JAX/Pallas
//!   artifacts and the vectorised speculation engine (paper §10 future
//!   work); gated behind the `pjrt` feature so the default build has no
//!   XLA dependency.
//! - [`util`] — PRNG, mini CLI, bench + property-test harnesses (the
//!   offline build has no clap/criterion/proptest).

pub mod analysis;
pub mod area;
pub mod coordinator;
pub mod fault;
pub mod ir;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sim;
pub mod transform;
pub mod util;
pub mod workloads;
