//! Static semantic lint for compiled DAE/SPEC modules.
//!
//! `ir/verify.rs` checks *structural* SSA well-formedness; this module
//! checks the *semantic* contracts the paper's transforms must preserve,
//! turning what used to be runtime fuzz findings into compile-time
//! diagnostics. Four rule families:
//!
//! - **DEC — decoupling legality** ([`slice`]): the access slice contains
//!   only address-generation work (no loads/stores/produces/poisons, no
//!   pops of CU-bound value channels) and the execute slice contains no
//!   request traffic; loss-of-decoupling consumes in the AGU are
//!   attributed to the sends that depend on them (via
//!   `analysis/defuse.rs` backward slices + `analysis/control_dep.rs`).
//! - **CHAN — channel-protocol balance** ([`channels`]): per channel,
//!   symbolic push/pop counts agree on every path and per loop iteration
//!   (path summaries over `analysis/loops.rs`, reducible CFGs only), so
//!   the slices can never statically desynchronize or deadlock.
//! - **POISON — poison soundness** ([`taint`]): every speculated store
//!   receives exactly one store value or poison per request on every
//!   path (the static shadow of the DU's Lemma 6.1 pairing), and a
//!   forward taint dataflow proves every speculatively consumed load
//!   value is guarded by the load's architectural home block before it
//!   reaches a store value or steers control flow.
//! - **SC — sequential-consistency preservation** ([`seqcst`]): the
//!   per-array store-request order in the AGU matches the per-array
//!   store-value/poison order in the CU (Lemma 6.1), and the CU's
//!   produce order matches the sequential program order of the original
//!   function (the paper's Theorem 6.2).
//!
//! Violations are structured [`Diagnostic`]s (rule id, severity,
//! function/block/instruction location, instruction text rendered with
//! `ir/printer.rs`). [`lint_compiled`] runs after `transform::build` on
//! every architecture in debug builds, the way `ir/verify.rs` already
//! does; `dae-spec lint` runs it from the CLI; the fuzz harness
//! cross-validates it by asserting every IR-level semantic mutation the
//! differential fuzzer can inject (dropped poison, dropped push, dropped
//! produce) is also flagged statically.

pub mod channels;
pub mod paths;
pub mod seqcst;
pub mod slice;
pub mod taint;

use crate::ir::{printer, Function, InstrId, Module};
use crate::transform::{Arch, Compiled, DaeProgram, SpecReqMap};
use std::fmt;

/// Lint rule families. `id()` is the stable tag printed in diagnostics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rule {
    /// Decoupling legality (slice op classes, LoD attribution).
    Decouple,
    /// Channel push/pop balance per path and per iteration.
    ChanBalance,
    /// Poison coverage and speculative-value taint.
    PoisonSound,
    /// Store-order preservation (Lemma 6.1 + Theorem 6.2).
    SeqCst,
    /// CFG reducibility — precondition of the path analysis itself.
    Reducible,
    /// The path enumerator hit its budget; affected region skipped.
    PathBudget,
}

impl Rule {
    pub fn id(self) -> &'static str {
        match self {
            Rule::Decouple => "DEC",
            Rule::ChanBalance => "CHAN",
            Rule::PoisonSound => "POISON",
            Rule::SeqCst => "SC",
            Rule::Reducible => "RED",
            Rule::PathBudget => "BUDGET",
        }
    }
}

/// Diagnostic severity. `Error` means the compiled module is unsound and
/// must not be simulated; `Warn` flags constructs that are suspicious but
/// have a sound reading; `Info` is attribution/bookkeeping (LoD chains,
/// skipped regions).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Info,
    Warn,
    Error,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warn => "warn",
            Severity::Error => "error",
        }
    }

    pub fn parse(s: &str) -> Option<Severity> {
        match s {
            "info" => Some(Severity::Info),
            "warn" => Some(Severity::Warn),
            "error" => Some(Severity::Error),
            _ => None,
        }
    }
}

/// One structured lint finding.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub rule: Rule,
    pub severity: Severity,
    /// Function the finding is in (slice name, e.g. `hist__cu`).
    pub func: String,
    /// Block name, when the finding anchors to a block.
    pub block: Option<String>,
    /// Offending instruction rendered with `ir/printer.rs`, when the
    /// finding anchors to one.
    pub instr: Option<String>,
    pub msg: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, out: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(out, "{}[{}] @{}", self.severity.name(), self.rule.id(), self.func)?;
        if let Some(b) = &self.block {
            write!(out, " {b}:")?;
        }
        write!(out, " {}", self.msg)?;
        if let Some(i) = &self.instr {
            write!(out, "\n    at: {i}")?;
        }
        Ok(())
    }
}

/// Result of linting one compiled module.
#[derive(Debug, Default)]
pub struct LintReport {
    pub diags: Vec<Diagnostic>,
}

impl LintReport {
    pub fn push(&mut self, d: Diagnostic) {
        self.diags.push(d);
    }

    pub fn has_errors(&self) -> bool {
        self.diags.iter().any(|d| d.severity == Severity::Error)
    }

    pub fn count_at_least(&self, min: Severity) -> usize {
        self.diags.iter().filter(|d| d.severity >= min).count()
    }

    /// Render every diagnostic at or above `min`, one per line group.
    pub fn render(&self, min: Severity) -> String {
        let mut s = String::new();
        for d in self.diags.iter().filter(|d| d.severity >= min) {
            s.push_str(&d.to_string());
            s.push('\n');
        }
        s
    }

    /// Does any diagnostic name `rule` at Error severity?
    pub fn has_error_for(&self, rule: Rule) -> bool {
        self.diags.iter().any(|d| d.rule == rule && d.severity == Severity::Error)
    }
}

/// Build a diagnostic anchored to instruction `iid` of `f`.
pub(crate) fn diag_at(
    rule: Rule,
    severity: Severity,
    m: &Module,
    f: &Function,
    iid: InstrId,
    msg: String,
) -> Diagnostic {
    Diagnostic {
        rule,
        severity,
        func: f.name.clone(),
        block: f.block_of_instr(iid).map(|b| f.block(b).name.clone()),
        instr: Some(printer::print_op(m, f, &f.instr(iid).op)),
        msg,
    }
}

/// Build a diagnostic anchored to a function (and optionally a block).
pub(crate) fn diag_fn(
    rule: Rule,
    severity: Severity,
    f: &Function,
    block: Option<String>,
    msg: String,
) -> Diagnostic {
    Diagnostic { rule, severity, func: f.name.clone(), block, instr: None, msg }
}

/// Lint one compiled architecture against the original module.
///
/// `orig` must be the module `transform::build` compiled from and
/// `func_idx` the compiled function — the SC program-order rule needs the
/// sequential store order of the source. For `Arch::Oracle` the
/// vs-original checks are skipped (LoD flattening intentionally changes
/// semantics); the intra-module rules still run.
pub fn lint_compiled(orig: &Module, func_idx: usize, c: &Compiled) -> LintReport {
    match c {
        Compiled::Monolithic { module, .. } => lint_monolithic(module),
        Compiled::Dae { program, arch, map, .. } => {
            let orig_pair = if *arch == Arch::Oracle {
                None
            } else {
                Some((orig, &orig.funcs[func_idx]))
            };
            lint_dae(orig_pair, program, map.as_ref())
        }
    }
}

/// Lint an STA module: a monolithic function must carry no channel
/// traffic at all.
pub fn lint_monolithic(m: &Module) -> LintReport {
    let mut r = LintReport::default();
    for f in &m.funcs {
        slice::check_no_channel_ops(m, f, &mut r);
    }
    r
}

/// Lint a decoupled program. Exposed separately from [`lint_compiled`]
/// so the fuzz harness can lint deliberately mutated `DaeProgram`s.
pub fn lint_dae(
    orig: Option<(&Module, &Function)>,
    p: &DaeProgram,
    map: Option<&SpecReqMap>,
) -> LintReport {
    let mut r = LintReport::default();
    slice::check_dae(p, &mut r);

    let agu = p.agu_fn();
    let cu = p.cu_fn();
    let shared = paths::shared_branches(agu, cu);
    let pa = paths::enumerate(&p.module, agu, &shared, &mut r);
    let pc = paths::enumerate(&p.module, cu, &shared, &mut r);
    if let (Some(pa), Some(pc)) = (&pa, &pc) {
        channels::check(p, pa, pc, &mut r);
        seqcst::check_store_streams(p, pa, pc, &mut r);
        if let Some(map) = map {
            taint::check(p, map, pa, pc, &mut r);
        }
    }

    if let Some((om, of)) = orig {
        let shared2 = paths::shared_branches(cu, of);
        let pc2 = paths::enumerate(&p.module, cu, &shared2, &mut r);
        let po = paths::enumerate(om, of, &shared2, &mut r);
        if let (Some(pc2), Some(po)) = (pc2, po) {
            seqcst::check_program_order(p, om, of, po, pc2, &mut r);
        }
    }
    r
}
