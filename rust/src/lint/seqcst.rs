//! Rule family SC — sequential-consistency preservation.
//!
//! Two layers, matching the paper's §6 argument:
//!
//! - **Lemma 6.1 (store-stream pairing)**: the DU pairs the k-th store
//!   *request* on an array's request stream with the k-th store
//!   *value/poison* on that array's value stream. Statically this means:
//!   per region and per shared-branch key, the mem-id sequence of
//!   `send_st_addr`s in the AGU must equal the mem-id sequence of
//!   `produce_val`/`poison_val`s in the CU.
//! - **Theorem 6.2 (program order)**: the CU's committed stores (its
//!   `produce_val`s — poisons are squashed requests) must appear in the
//!   sequential program order of the original function, per array and per
//!   matched path.

use super::paths::{self, EvKind, FnPaths, Key, PathEvent, RegionPaths};
use super::{diag_at, diag_fn, LintReport, Rule, Severity};
use crate::ir::{Function, InstrId, Module};
use crate::transform::DaeProgram;
use std::collections::BTreeSet;

/// Per key: the mem-id sequence of matching events, skipping paths whose
/// matching events include an unresolved ("maybe") one. Intra-key
/// disagreement is reported and the key dropped.
fn stream_by_key(
    m: &Module,
    f: &Function,
    region: &RegionPaths,
    filter: &dyn Fn(&PathEvent) -> bool,
    rule: Rule,
    what: &str,
    r: &mut LintReport,
) -> Vec<(Key, Vec<u32>, Option<InstrId>)> {
    let mut out = Vec::new();
    for (key, group) in paths::group_by_key(&region.paths) {
        let mut rep: Option<(Vec<u32>, Option<InstrId>)> = None;
        let mut ok = true;
        for p in &group {
            let evs: Vec<&PathEvent> = p.events.iter().filter(|e| filter(e)).collect();
            if evs.iter().any(|e| !e.definite) {
                continue; // order not statically resolvable on this path
            }
            let seq: Vec<u32> = evs.iter().map(|e| e.mem).collect();
            let sample = evs.first().map(|e| e.iid);
            match &rep {
                None => rep = Some((seq, sample)),
                Some((prev, psample)) if *prev != seq => {
                    let msg = format!(
                        "{what}: paths with identical shared-branch decisions [{}] emit \
                         different store streams {:?} vs {:?}",
                        paths::key_str(&key),
                        prev,
                        seq,
                    );
                    match sample.or(*psample) {
                        Some(iid) => r.push(diag_at(rule, Severity::Error, m, f, iid, msg)),
                        None => {
                            r.push(diag_fn(rule, Severity::Error, f, region.name.clone(), msg))
                        }
                    }
                    ok = false;
                    break;
                }
                Some(_) => {}
            }
        }
        if ok {
            if let Some((seq, sample)) = rep {
                out.push((key, seq, sample));
            }
        }
    }
    out
}

/// Compare two sides' per-key streams (matched keys exactly, unmatched
/// keys leniently against the whole partner set). Each side carries its
/// own (module, function) pair for diagnostic rendering.
#[allow(clippy::too_many_arguments)]
fn compare_seq_sides(
    ma: &Module,
    fa: &Function,
    sa: &[(Key, Vec<u32>, Option<InstrId>)],
    mb: &Module,
    fb: &Function,
    sb: &[(Key, Vec<u32>, Option<InstrId>)],
    rule: Rule,
    what: &str,
    r: &mut LintReport,
) {
    let mut one_side = |ours: &[(Key, Vec<u32>, Option<InstrId>)],
                        theirs: &[(Key, Vec<u32>, Option<InstrId>)],
                        m: &Module,
                        f: &Function,
                        r: &mut LintReport| {
        for (key, seq, sample) in ours {
            let verdict = match theirs.iter().find(|(k, _, _)| k == key) {
                Some((_, oseq, _)) => oseq == seq,
                None if theirs.is_empty() => seq.is_empty(),
                None => theirs.iter().any(|(_, oseq, _)| oseq == seq),
            };
            if !verdict {
                let msg = format!(
                    "{what}: on paths [{}] this side's store stream is {:?}, which no \
                     matching partner path emits",
                    paths::key_str(key),
                    seq,
                );
                match sample {
                    Some(iid) => r.push(diag_at(rule, Severity::Error, m, f, *iid, msg)),
                    None => r.push(diag_fn(rule, Severity::Error, f, None, msg)),
                }
            }
        }
    };
    one_side(sa, sb, ma, fa, r);
    one_side(sb, sa, mb, fb, r);
}

/// Lemma 6.1: AGU store-request order vs CU store-value/poison order,
/// per array, per region, per key.
pub fn check_store_streams(p: &DaeProgram, pa: &FnPaths, pc: &FnPaths, r: &mut LintReport) {
    let m = &p.module;
    let agu = p.agu_fn();
    let cu = p.cu_fn();
    let store_arrs: BTreeSet<u32> =
        p.mem_ops.iter().filter(|mo| mo.is_store).map(|mo| mo.arr.0).collect();
    for (ra, rc) in paths::match_regions(pa, pc) {
        let (ra, rc) = match (ra, rc) {
            (Some(ra), Some(rc)) => (ra, rc),
            _ => continue, // missing region: CHAN already covers counts
        };
        if ra.truncated || rc.truncated {
            continue;
        }
        for &arr in &store_arrs {
            let what = format!("store-order (Lemma 6.1) on array {arr}");
            let sa = stream_by_key(
                m,
                agu,
                ra,
                &|e| e.kind == EvKind::SendSt && e.arr == arr,
                Rule::SeqCst,
                &what,
                r,
            );
            let sc = stream_by_key(
                m,
                cu,
                rc,
                &|e| matches!(e.kind, EvKind::Produce | EvKind::Poison) && e.arr == arr,
                Rule::SeqCst,
                &what,
                r,
            );
            compare_seq_sides(m, agu, &sa, m, cu, &sc, Rule::SeqCst, &what, r);
        }
    }
}

/// Theorem 6.2: the CU's produce order equals the original function's
/// sequential store order, per array, per matched path.
pub fn check_program_order(
    p: &DaeProgram,
    om: &Module,
    of: &Function,
    po: FnPaths,
    pc: FnPaths,
    r: &mut LintReport,
) {
    let m = &p.module;
    let cu = p.cu_fn();
    let store_arrs: BTreeSet<u32> =
        p.mem_ops.iter().filter(|mo| mo.is_store).map(|mo| mo.arr.0).collect();
    for (ro, rc) in paths::match_regions(&po, &pc) {
        let (ro, rc) = match (ro, rc) {
            (Some(ro), Some(rc)) => (ro, rc),
            _ => continue,
        };
        if ro.truncated || rc.truncated {
            continue;
        }
        for &arr in &store_arrs {
            let what = format!("program order (Theorem 6.2) on array {arr}");
            let so = stream_by_key(
                om,
                of,
                ro,
                &|e| e.kind == EvKind::Store && e.arr == arr,
                Rule::SeqCst,
                &what,
                r,
            );
            let sc = stream_by_key(
                m,
                cu,
                rc,
                &|e| e.kind == EvKind::Produce && e.arr == arr,
                Rule::SeqCst,
                &what,
                r,
            );
            compare_seq_sides(om, of, &so, m, cu, &sc, Rule::SeqCst, &what, r);
        }
    }
}
