//! Rule family CHAN — channel-protocol balance.
//!
//! For every channel the machine will allocate (per-mem load
//! request/value pairs, per-array store-value streams) the number of
//! pushes and pops must agree on every path and per loop iteration —
//! otherwise the slices drift apart and eventually deadlock or pair the
//! wrong elements. The check works on [`super::paths`] summaries:
//!
//! - within one function, paths that share a key (identical decisions at
//!   all branches shared with the partner slice) must have intersecting
//!   count intervals — the partner cannot tell such paths apart, so a
//!   difference is un-mirrorable;
//! - across functions, matched keys must have intersecting intervals;
//! - a key only one side has (a branch the other slice folded away) is
//!   checked leniently: its interval must be compatible with *some*
//!   partner path of the region.

use super::paths::{self, EvKind, FnPaths, Key, PathEvent, RegionPaths};
use super::{diag_at, diag_fn, LintReport, Rule, Severity};
use crate::ir::{Function, InstrId, Module};
use crate::transform::DaeProgram;
use std::collections::BTreeSet;

/// Per-key combined interval with a sample instruction for diagnostics.
struct KeyInterval {
    key: Key,
    lo: u32,
    hi: u32,
    sample: Option<InstrId>,
}

/// Combine per-path intervals per key; an empty intra-key intersection is
/// reported and the key dropped.
fn collect(
    m: &Module,
    f: &Function,
    region: &RegionPaths,
    tag: &dyn Fn(&PathEvent) -> bool,
    rule: Rule,
    what: &str,
    r: &mut LintReport,
) -> Vec<KeyInterval> {
    let mut out = Vec::new();
    for (key, group) in paths::group_by_key(&region.paths) {
        let mut lo = 0u32;
        let mut hi = u32::MAX;
        let mut sample = None;
        for p in &group {
            let (plo, phi) = paths::count_interval(p, tag);
            lo = lo.max(plo);
            hi = hi.min(phi);
            if sample.is_none() {
                sample = paths::first_event(p, tag).map(|e| e.iid);
            }
        }
        if lo > hi {
            let msg = format!(
                "unbalanced {what}: paths with identical shared-branch decisions [{}] \
                 disagree on the event count (between {} and {} per iteration)",
                paths::key_str(&key),
                group.iter().map(|p| paths::count_interval(p, tag).0).min().unwrap_or(0),
                lo,
            );
            match sample {
                Some(iid) => r.push(diag_at(rule, Severity::Error, m, f, iid, msg)),
                None => r.push(diag_fn(rule, Severity::Error, f, region.name.clone(), msg)),
            }
            continue;
        }
        out.push(KeyInterval { key, lo, hi, sample });
    }
    out
}

fn intersects(a: &KeyInterval, b: &KeyInterval) -> bool {
    a.lo.max(b.lo) <= a.hi.min(b.hi)
}

/// Check one (tag-on-side-A, tag-on-side-B) pair over one region pair.
#[allow(clippy::too_many_arguments)]
pub(crate) fn check_balance(
    m: &Module,
    fa: &Function,
    ra: Option<&RegionPaths>,
    fb: &Function,
    rb: Option<&RegionPaths>,
    tag_a: &dyn Fn(&PathEvent) -> bool,
    tag_b: &dyn Fn(&PathEvent) -> bool,
    rule: Rule,
    what: &str,
    r: &mut LintReport,
) {
    let empty = RegionPaths {
        name: None,
        paths: vec![paths::PathSummary { key: vec![], events: vec![] }],
        truncated: false,
    };
    let (ra, rb) = (ra.unwrap_or(&empty), rb.unwrap_or(&empty));
    if ra.truncated || rb.truncated {
        return; // already surfaced as a BUDGET diagnostic
    }
    let ia = collect(m, fa, ra, tag_a, rule, what, r);
    let ib = collect(m, fb, rb, tag_b, rule, what, r);

    let mut cross = |ours: &[KeyInterval],
                     theirs: &[KeyInterval],
                     f: &Function,
                     region: &RegionPaths,
                     r: &mut LintReport| {
        for ki in ours {
            let verdict = match theirs.iter().find(|kj| kj.key == ki.key) {
                Some(kj) => intersects(ki, kj),
                // Unmatched key: the other side folded this branch away;
                // accept if any of its paths could mirror our count.
                None if theirs.is_empty() => ki.lo == 0,
                None => theirs.iter().any(|kj| intersects(ki, kj)),
            };
            if !verdict {
                let msg = format!(
                    "unbalanced {what}: on paths [{}] this slice sees {}..{} events \
                     per iteration but the partner slice cannot match it",
                    paths::key_str(&ki.key),
                    ki.lo,
                    if ki.hi == u32::MAX { ki.lo } else { ki.hi },
                );
                match ki.sample {
                    Some(iid) => r.push(diag_at(rule, Severity::Error, m, f, iid, msg)),
                    None => r.push(diag_fn(rule, Severity::Error, f, region.name.clone(), msg)),
                }
            }
        }
    };
    cross(&ia, &ib, fa, ra, r);
    cross(&ib, &ia, fb, rb, r);
}

/// All CHAN checks for one decoupled program.
pub fn check(p: &DaeProgram, pa: &FnPaths, pc: &FnPaths, r: &mut LintReport) {
    let m = &p.module;
    let agu = p.agu_fn();
    let cu = p.cu_fn();

    for (ra, rc) in paths::match_regions(pa, pc) {
        // Per CU-consumed load: one request in the AGU per value popped
        // in the CU.
        for &mem in &p.cu_consumes {
            check_balance(
                m,
                agu,
                ra,
                cu,
                rc,
                &|e| e.kind == EvKind::SendLd && e.mem == mem,
                &|e| e.kind == EvKind::ConsumeCu && e.mem == mem,
                Rule::ChanBalance,
                &format!("load m{mem} request/value traffic"),
                r,
            );
        }
        // Per array with store traffic: one store request per store
        // value or poison.
        let store_arrs: BTreeSet<u32> =
            p.mem_ops.iter().filter(|mo| mo.is_store).map(|mo| mo.arr.0).collect();
        for &arr in &store_arrs {
            check_balance(
                m,
                agu,
                ra,
                cu,
                rc,
                &|e| e.kind == EvKind::SendSt && e.arr == arr,
                &|e| matches!(e.kind, EvKind::Produce | EvKind::Poison) && e.arr == arr,
                Rule::ChanBalance,
                &format!("store traffic on array {arr} (requests vs values+poisons)"),
                r,
            );
        }
    }

    // AGU-internal LoD balance: a send and its own consume travel the
    // same paths, so the counts must agree exactly path by path.
    for &mem in &p.agu_consumes {
        for region in &pa.regions {
            if region.truncated {
                continue;
            }
            for path in &region.paths {
                let (sends, _) =
                    paths::count_interval(path, |e| e.kind == EvKind::SendLd && e.mem == mem);
                let (pops, _) =
                    paths::count_interval(path, |e| e.kind == EvKind::ConsumeAgu && e.mem == mem);
                if sends != pops {
                    let sample = paths::first_event(path, |e| {
                        e.mem == mem && matches!(e.kind, EvKind::SendLd | EvKind::ConsumeAgu)
                    })
                    .map(|e| e.iid);
                    let msg = format!(
                        "LoD desync for m{mem}: path [{}] sends {sends} request(s) but pops \
                         {pops} value(s)",
                        paths::key_str(&path.key),
                    );
                    match sample {
                        Some(iid) => {
                            r.push(diag_at(Rule::ChanBalance, Severity::Error, m, agu, iid, msg))
                        }
                        None => r.push(diag_fn(
                            Rule::ChanBalance,
                            Severity::Error,
                            agu,
                            region.name.clone(),
                            msg,
                        )),
                    }
                }
            }
        }
    }
}
