//! Per-iteration path summaries over reducible CFGs.
//!
//! The balance/ordering rules compare *paths* between the AGU, the CU and
//! the original function. Those are three different CFGs, but all clones
//! of one source: surviving blocks keep their names across `decouple`,
//! hoisting and `simplify_cfg` (which folds and bypasses blocks but never
//! renames or swaps a `condbr`'s taken/not-taken slots). That shared
//! naming is what lets two functions' paths be matched without any side
//! table:
//!
//! - the CFG is cut into **regions** — the top level plus one region per
//!   natural loop (identified by its header's *name*); a region's paths
//!   describe exactly one iteration, so counts per path are counts per
//!   iteration;
//! - inner loops are collapsed to summary nodes (enter at the header,
//!   leave by each distinct exit target) — their events belong to the
//!   inner region;
//! - each path carries a **key**: the branch decisions taken at blocks
//!   that are genuine two-way branches in *both* functions being
//!   compared. Branches only one side still has (e.g. a CU guard whose
//!   AGU twin folded away after hoisting) contribute no key token, which
//!   is precisely what makes same-key paths on one side comparable: no
//!   shared branch separates them, so the other side cannot tell them
//!   apart and their channel traffic must agree.
//!
//! Poison steering predicates (Algorithm 3 case 2) are pure
//! `const.b`/φ networks, so a per-path symbolic boolean environment
//! resolves them exactly; anything unresolved degrades the affected
//! event to a "maybe" and the consumers work with count intervals.

use super::{LintReport, Rule, Severity};
use crate::analysis::{DomTree, LoopInfo};
use crate::ir::{BlockId, ChanKind, Function, InstrId, Module, Op, Terminator, ValueDef, ValueId};
use std::collections::{BTreeSet, HashMap, HashSet};

/// Per-region path budget. Regions are single loop iterations, so real
/// kernels sit far below this; hitting it degrades to a BUDGET info
/// diagnostic rather than wrong answers.
pub const PATH_CAP: usize = 2048;

/// What an event on a path is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvKind {
    /// AGU `send_ld_addr`.
    SendLd,
    /// AGU `send_st_addr`.
    SendSt,
    /// `consume_val` of a `ld_val` channel (CU-bound value pop).
    ConsumeCu,
    /// `consume_val` of a `ld_val_agu` channel (AGU LoD pop).
    ConsumeAgu,
    /// CU `produce_val`.
    Produce,
    /// CU `poison_val` (definite unless its steering pred is unresolved).
    Poison,
    /// `load` in the original function (mem-tagged in layout order).
    Load,
    /// `store` in the original function.
    Store,
}

/// One channel/memory event observed on a path.
#[derive(Clone, Copy, Debug)]
pub struct PathEvent {
    pub kind: EvKind,
    /// Static memory-op id (layout order of the original function).
    pub mem: u32,
    /// Array the event's channel/memory op refers to.
    pub arr: u32,
    pub iid: InstrId,
    /// False only for `poison_val` whose steering predicate could not be
    /// resolved on this path.
    pub definite: bool,
}

/// A branch-decision token: `"<block>:t"`, `"<block>:f"`, or
/// `"<header>=><target>"` for the exit taken out of a summarized inner
/// loop.
pub type Key = Vec<String>;

#[derive(Clone, Debug)]
pub struct PathSummary {
    pub key: Key,
    pub events: Vec<PathEvent>,
}

/// All per-iteration paths of one region.
#[derive(Debug)]
pub struct RegionPaths {
    /// Loop-header block name; `None` for the top-level region.
    pub name: Option<String>,
    pub paths: Vec<PathSummary>,
    pub truncated: bool,
}

/// All regions of one function.
#[derive(Debug)]
pub struct FnPaths {
    pub fname: String,
    pub regions: Vec<RegionPaths>,
}

impl FnPaths {
    pub fn region(&self, name: Option<&str>) -> Option<&RegionPaths> {
        self.regions.iter().find(|r| r.name.as_deref() == name)
    }
}

/// Names of blocks that are genuine two-way branches in both functions.
pub fn shared_branches(a: &Function, b: &Function) -> HashSet<String> {
    let branch_names = |f: &Function| -> HashSet<String> {
        f.blocks
            .iter()
            .filter(|bl| matches!(&bl.term, Terminator::CondBr { t, f: fa, .. } if t != fa))
            .map(|bl| bl.name.clone())
            .collect()
    };
    branch_names(a).intersection(&branch_names(b)).cloned().collect()
}

/// Mem tags for `load`/`store` instructions of an original (pre-
/// decoupling) function, in layout order — the same enumeration
/// `transform::decouple` uses, so tags line up with `MemOpInfo::mem`.
pub fn original_mem_tags(f: &Function) -> Vec<Option<u32>> {
    let mut tags = vec![None; f.instrs.len()];
    let mut next = 0u32;
    for b in &f.blocks {
        for &iid in &b.instrs {
            if f.instr(iid).op.is_memory() {
                tags[iid.index()] = Some(next);
                next += 1;
            }
        }
    }
    tags
}

/// Enumerate per-iteration path summaries for every region of `f`.
/// Returns `None` (with a RED error pushed) if the CFG is irreducible —
/// the transforms' stated precondition, without which regions are not
/// well defined.
pub fn enumerate(
    m: &Module,
    f: &Function,
    shared: &HashSet<String>,
    r: &mut LintReport,
) -> Option<FnPaths> {
    let dom = DomTree::new(f);
    let loops = LoopInfo::new(f, &dom);
    if !loops.reducible {
        r.push(super::diag_fn(
            Rule::Reducible,
            Severity::Error,
            f,
            None,
            "irreducible control flow: path analysis is not defined".into(),
        ));
        return None;
    }
    let mem_tags = original_mem_tags(f);
    let mut regions = Vec::new();
    {
        let mut w = Walker { m, f, loops: &loops, region: None, shared, mem_tags: &mem_tags, paths: Vec::new(), truncated: false };
        w.start(f.entry, false);
        regions.push(RegionPaths { name: None, paths: w.paths, truncated: w.truncated });
    }
    for (li, l) in loops.loops.iter().enumerate() {
        let mut w = Walker { m, f, loops: &loops, region: Some(li), shared, mem_tags: &mem_tags, paths: Vec::new(), truncated: false };
        w.start(l.header, true);
        regions.push(RegionPaths {
            name: Some(f.block(l.header).name.clone()),
            paths: w.paths,
            truncated: w.truncated,
        });
    }
    for reg in regions.iter().filter(|rg| rg.truncated) {
        r.push(super::diag_fn(
            Rule::PathBudget,
            Severity::Info,
            f,
            reg.name.clone(),
            format!("region exceeded {PATH_CAP} paths; its balance checks were skipped"),
        ));
    }
    Some(FnPaths { fname: f.name.clone(), regions })
}

struct Walker<'a> {
    m: &'a Module,
    f: &'a Function,
    loops: &'a LoopInfo,
    /// Index into `loops.loops`, or `None` for the top-level region.
    region: Option<usize>,
    shared: &'a HashSet<String>,
    mem_tags: &'a [Option<u32>],
    paths: Vec<PathSummary>,
    truncated: bool,
}

#[derive(Clone, Default)]
struct PathState {
    env: HashMap<ValueId, bool>,
    key: Key,
    events: Vec<PathEvent>,
    visited: HashSet<BlockId>,
}

impl Walker<'_> {
    fn start(&mut self, b: BlockId, is_loop_header: bool) {
        let st = PathState::default();
        if is_loop_header {
            // A loop region starts at its own header; bypass the
            // backedge check `enter` would apply.
            self.step(b, None, st);
        } else {
            self.advance(b, st);
        }
    }

    fn resolve(&self, env: &HashMap<ValueId, bool>, v: ValueId) -> Option<bool> {
        if let Some(&b) = env.get(&v) {
            return Some(b);
        }
        if let ValueDef::Instr(iid) = self.f.value(v).def {
            if let Op::ConstB(b) = self.f.instr(iid).op {
                return Some(b);
            }
        }
        None
    }

    /// Process a block known to belong to this region, then fan out.
    fn step(&mut self, b: BlockId, prev: Option<BlockId>, mut st: PathState) {
        if self.paths.len() >= PATH_CAP || !st.visited.insert(b) {
            self.truncated = true;
            return;
        }
        // φ resolution from the taken edge (parallel-assignment
        // semantics: read the old environment for every φ first).
        if let Some(p) = prev {
            let mut updates = Vec::new();
            for &iid in &self.f.block(b).instrs {
                let instr = self.f.instr(iid);
                if let Op::Phi { incomings, .. } = &instr.op {
                    if let (Some(res), Some(&(_, v))) =
                        (instr.result, incomings.iter().find(|(pb, _)| *pb == p))
                    {
                        if let Some(val) = self.resolve(&st.env, v) {
                            updates.push((res, val));
                        }
                    }
                }
            }
            for (res, val) in updates {
                st.env.insert(res, val);
            }
        }
        for &iid in &self.f.block(b).instrs {
            let instr = self.f.instr(iid);
            let mut ev = |kind: EvKind, mem: u32, arr: u32, definite: bool| {
                st.events.push(PathEvent { kind, mem, arr, iid, definite });
            };
            match &instr.op {
                Op::ConstB(v) => {
                    if let Some(res) = instr.result {
                        st.env.insert(res, *v);
                    }
                }
                Op::Not(a) => {
                    if let (Some(res), Some(v)) = (instr.result, self.resolve(&st.env, *a)) {
                        st.env.insert(res, !v);
                    }
                }
                Op::SendLdAddr { chan, mem, .. } => {
                    ev(EvKind::SendLd, *mem, self.m.chan(*chan).arr.0, true)
                }
                Op::SendStAddr { chan, mem, .. } => {
                    ev(EvKind::SendSt, *mem, self.m.chan(*chan).arr.0, true)
                }
                Op::ConsumeVal { chan, mem, .. } => {
                    let c = self.m.chan(*chan);
                    let kind = if c.kind == ChanKind::LdValAgu {
                        EvKind::ConsumeAgu
                    } else {
                        EvKind::ConsumeCu
                    };
                    ev(kind, *mem, c.arr.0, true)
                }
                Op::ProduceVal { chan, mem, .. } => {
                    ev(EvKind::Produce, *mem, self.m.chan(*chan).arr.0, true)
                }
                Op::PoisonVal { chan, mem, pred } => {
                    let arr = self.m.chan(*chan).arr.0;
                    match pred.map(|p| self.resolve(&st.env, p)) {
                        Some(Some(false)) => {} // steered off on this path
                        None | Some(Some(true)) => ev(EvKind::Poison, *mem, arr, true),
                        Some(None) => ev(EvKind::Poison, *mem, arr, false),
                    }
                }
                Op::Load { arr, .. } => {
                    if let Some(mem) = self.mem_tags[iid.index()] {
                        ev(EvKind::Load, mem, arr.0, true)
                    }
                }
                Op::Store { arr, .. } => {
                    if let Some(mem) = self.mem_tags[iid.index()] {
                        ev(EvKind::Store, mem, arr.0, true)
                    }
                }
                _ => {}
            }
        }
        match &self.f.block(b).term {
            Terminator::Ret | Terminator::Unterminated => self.finish(st),
            Terminator::Br(t) => self.enter(*t, b, st),
            Terminator::CondBr { t, f: fb, .. } if t == fb => self.enter(*t, b, st),
            Terminator::CondBr { cond, t, f: fb } => {
                let name = &self.f.block(b).name;
                let keyed = self.shared.contains(name);
                let arms: Vec<(BlockId, bool)> = match self.resolve(&st.env, *cond) {
                    Some(true) => vec![(*t, true)],
                    Some(false) => vec![(*fb, false)],
                    None => vec![(*t, true), (*fb, false)],
                };
                for (succ, taken) in arms {
                    let mut st2 = st.clone();
                    if keyed {
                        st2.key.push(format!("{name}:{}", if taken { 't' } else { 'f' }));
                    }
                    self.enter(succ, b, st2);
                }
            }
        }
    }

    /// Follow the edge into `s`, honouring region boundaries.
    fn enter(&mut self, s: BlockId, from: BlockId, st: PathState) {
        if let Some(li) = self.region {
            let l = &self.loops.loops[li];
            if s == l.header || !l.contains(s) {
                // Backedge (one iteration done) or loop exit.
                self.finish(st);
                return;
            }
        }
        self.advance_from(s, Some(from), st);
    }

    /// Entry point that does not apply region-boundary checks (used for
    /// the region's own start block).
    fn advance(&mut self, s: BlockId, st: PathState) {
        self.advance_from(s, None, st);
    }

    fn advance_from(&mut self, s: BlockId, prev: Option<BlockId>, st: PathState) {
        if self.loops.innermost_idx(s) == self.region {
            self.step(s, prev, st);
            return;
        }
        // `s` enters a nested loop: summarize the whole nest directly
        // under this region and continue from each distinct exit target.
        let mut li = match self.loops.innermost_idx(s) {
            Some(li) => li,
            None => {
                // Outside every loop while the region is a loop — only
                // reachable via enter(), which already handled exits.
                self.finish(st);
                return;
            }
        };
        while self.loops.loops[li].parent != self.region {
            match self.loops.loops[li].parent {
                Some(p) => li = p,
                None => break,
            }
        }
        let inner = &self.loops.loops[li];
        let mut targets: BTreeSet<BlockId> = BTreeSet::new();
        for &u in &inner.blocks {
            for v in self.f.succs(u) {
                if !inner.contains(v) {
                    targets.insert(v);
                }
            }
        }
        if targets.is_empty() {
            // Infinite loop: the path never returns to this region.
            self.finish(st);
            return;
        }
        let multi = targets.len() > 1;
        let hname = self.f.block(inner.header).name.clone();
        for v in targets {
            let mut st2 = st.clone();
            if multi {
                st2.key.push(format!("{hname}=>{}", self.f.block(v).name));
            }
            // φs at `v` see an edge from inside the summarized loop; the
            // environment across it is unknown, so pass no predecessor.
            if let Some(rli) = self.region {
                let l = &self.loops.loops[rli];
                if v == l.header || !l.contains(v) {
                    self.finish(st2);
                    continue;
                }
            }
            self.advance_from(v, None, st2);
        }
    }

    fn finish(&mut self, st: PathState) {
        if self.paths.len() >= PATH_CAP {
            self.truncated = true;
            return;
        }
        self.paths.push(PathSummary { key: st.key, events: st.events });
    }
}

/// `[lo, hi]` occurrence interval of events matching `pred` on `p`.
pub fn count_interval(p: &PathSummary, pred: impl Fn(&PathEvent) -> bool) -> (u32, u32) {
    let mut lo = 0;
    let mut hi = 0;
    for e in p.events.iter().filter(|e| pred(e)) {
        hi += 1;
        if e.definite {
            lo += 1;
        }
    }
    (lo, hi)
}

/// First event matching `pred` on `p`, for diagnostic anchoring.
pub fn first_event<'a>(
    p: &'a PathSummary,
    pred: impl Fn(&PathEvent) -> bool,
) -> Option<&'a PathEvent> {
    p.events.iter().find(|e| pred(e))
}

/// Group a region's paths by key (deterministic order).
pub fn group_by_key(paths: &[PathSummary]) -> Vec<(Key, Vec<&PathSummary>)> {
    let mut groups: Vec<(Key, Vec<&PathSummary>)> = Vec::new();
    for p in paths {
        match groups.iter_mut().find(|(k, _)| *k == p.key) {
            Some((_, v)) => v.push(p),
            None => groups.push((p.key.clone(), vec![p])),
        }
    }
    groups.sort_by(|a, b| a.0.cmp(&b.0));
    groups
}

/// Render a key for diagnostics.
pub fn key_str(k: &Key) -> String {
    if k.is_empty() {
        "<any>".to_string()
    } else {
        k.join(" ")
    }
}

/// Pair up regions of two functions: by header name first, leftovers
/// zipped in order (clone lineage keeps names aligned; the fallback only
/// matters if a header was renamed by a CFG cleanup). Regions with no
/// partner at all come back with `None` on the other side.
pub fn match_regions<'a>(
    a: &'a FnPaths,
    b: &'a FnPaths,
) -> Vec<(Option<&'a RegionPaths>, Option<&'a RegionPaths>)> {
    let mut used = vec![false; b.regions.len()];
    let mut out = Vec::new();
    let mut a_left = Vec::new();
    for ra in &a.regions {
        match b.regions.iter().enumerate().position(|(i, rb)| !used[i] && rb.name == ra.name) {
            Some(i) => {
                used[i] = true;
                out.push((Some(ra), Some(&b.regions[i])));
            }
            None => a_left.push(ra),
        }
    }
    let mut b_left: Vec<&RegionPaths> =
        b.regions.iter().enumerate().filter(|(i, _)| !used[*i]).map(|(_, rg)| rg).collect();
    for ra in a_left {
        let rb = if b_left.is_empty() { None } else { Some(b_left.remove(0)) };
        out.push((Some(ra), rb));
    }
    for rb in b_left {
        out.push((None, Some(rb)));
    }
    out
}
