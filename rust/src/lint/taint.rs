//! Rule family POISON — poison soundness for speculated memory ops.
//!
//! Two obligations, both specific to SPEC builds (a `SpecReqMap` exists):
//!
//! - **Coverage**: every speculatively hoisted store must receive exactly
//!   one store value *or poison* per hoisted request, on every path —
//!   the per-mem shadow of the DU's Lemma 6.1 pairing. This is what the
//!   fuzzer's `DropPoison` mutation breaks: the path that should poison
//!   falls to zero pushes while its shared-key siblings still push one.
//! - **Guardedness (forward taint)**: a speculated load's value is popped
//!   at the hoist site, i.e. possibly on paths where the original
//!   program never executed the load (an over-read). Such a value is
//!   architecturally meaningful only once control reaches the load's
//!   original home block (`SpecReq::true_bb`). The taint walk
//!   (`analysis/defuse.rs` forward slice from each speculative consume)
//!   therefore requires every sink to be unreachable from the consume
//!   without passing the home block: reaching a `produce_val` that way is
//!   an error (a possibly-bogus value can commit), steering a branch
//!   that way is a warning (control mis-steering is recoverable only if
//!   every store behind it is itself poison-covered).

use super::channels::check_balance;
use super::paths::{self, EvKind, FnPaths};
use super::{diag_at, LintReport, Rule, Severity};
use crate::analysis::DefUse;
use crate::ir::{BlockId, Function, InstrId, Op, ValueId};
use crate::transform::{DaeProgram, SpecReqMap};
use std::collections::{HashMap, HashSet, VecDeque};

/// Is `target` reachable from `start` on any CFG path that never enters
/// `avoid`? (`target == avoid` is therefore always false.)
fn reaches_avoiding(f: &Function, start: BlockId, target: BlockId, avoid: BlockId) -> bool {
    if start == avoid || target == avoid {
        return start == target && start != avoid;
    }
    if start == target {
        return true;
    }
    let mut seen = vec![false; f.num_blocks()];
    let mut q = VecDeque::from([start]);
    seen[start.index()] = true;
    while let Some(b) = q.pop_front() {
        for s in f.succs(b) {
            if s == avoid || seen[s.index()] {
                continue;
            }
            if s == target {
                return true;
            }
            seen[s.index()] = true;
            q.push_back(s);
        }
    }
    false
}

pub fn check(p: &DaeProgram, map: &SpecReqMap, pa: &FnPaths, pc: &FnPaths, r: &mut LintReport) {
    let m = &p.module;
    let agu = p.agu_fn();
    let cu = p.cu_fn();

    let mut spec_stores: Vec<u32> = Vec::new();
    let mut spec_loads: HashMap<u32, BlockId> = HashMap::new();
    for (_, reqs) in map.iter() {
        for req in reqs {
            if req.is_store {
                spec_stores.push(req.mem);
            } else {
                spec_loads.insert(req.mem, req.true_bb);
            }
        }
    }

    // -- coverage: per speculated store, requests vs values+poisons ---------
    for &smem in &spec_stores {
        for (ra, rc) in paths::match_regions(pa, pc) {
            check_balance(
                m,
                agu,
                ra,
                cu,
                rc,
                &|e| e.kind == EvKind::SendSt && e.mem == smem,
                &|e| matches!(e.kind, EvKind::Produce | EvKind::Poison) && e.mem == smem,
                Rule::PoisonSound,
                &format!("speculated store m{smem} (hoisted requests vs values+poisons)"),
                r,
            );
        }
    }

    // -- guardedness: forward taint from speculative consumes ---------------
    let du = DefUse::new(cu);
    for b in &cu.blocks {
        for &iid in &b.instrs {
            let (mem, res) = match (&cu.instr(iid).op, cu.instr(iid).result) {
                (Op::ConsumeVal { mem, .. }, Some(res)) => (*mem, res),
                _ => continue,
            };
            let Some(&home) = spec_loads.get(&mem) else { continue };
            let Some(cb) = cu.block_of_instr(iid) else { continue };
            if cb == home {
                continue; // consume still at the load's home: never early
            }
            let tainted_instrs = du.forward_slice(cu, &[res]);
            let mut tainted_vals: Vec<ValueId> = vec![res];
            tainted_vals.extend(tainted_instrs.iter().filter_map(|&ti| cu.instr(ti).result));
            let tainted_set: HashSet<InstrId> = tainted_instrs.iter().copied().collect();

            // Value sinks: a produce_val built from the speculative value.
            for &ti in &tainted_set {
                if !matches!(cu.instr(ti).op, Op::ProduceVal { .. }) {
                    continue;
                }
                let Some(x) = cu.block_of_instr(ti) else { continue };
                if reaches_avoiding(cu, cb, x, home) {
                    r.push(diag_at(
                        Rule::PoisonSound,
                        Severity::Error,
                        m,
                        cu,
                        ti,
                        format!(
                            "speculatively consumed value of load m{mem} can reach this \
                             store value without passing the load's home block `{}`",
                            cu.block(home).name
                        ),
                    ));
                }
            }
            // Control sinks: a branch steered by the speculative value.
            let mut warned: HashSet<BlockId> = HashSet::new();
            for &v in &tainted_vals {
                for &x in du.term_users(v) {
                    if warned.contains(&x) || !reaches_avoiding(cu, cb, x, home) {
                        continue;
                    }
                    warned.insert(x);
                    r.push(super::diag_fn(
                        Rule::PoisonSound,
                        Severity::Warn,
                        cu,
                        Some(cu.block(x).name.clone()),
                        format!(
                            "branch steered by the speculatively consumed value of load \
                             m{mem} on a path that avoids its home block `{}`",
                            cu.block(home).name
                        ),
                    ));
                }
            }
        }
    }
}
