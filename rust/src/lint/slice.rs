//! Rule family DEC — decoupling legality.
//!
//! After `decouple` (and any amount of hoisting/cleanup) the access
//! slice must contain only address-generation work and the execute slice
//! only value work; a raw `load`/`store` or a misdirected channel op in
//! either slice means decoupling was silently lost. Loss-of-decoupling
//! consumes in the AGU are legal (that is what `ld_val_agu` channels are
//! for) but worth surfacing: each send whose backward slice
//! (`analysis/defuse.rs`, with the Definition 4.1 φ-terminator
//! refinement) or control dependences (`analysis/control_dep.rs`) reach
//! a consumed value is attributed to that LoD chain as an Info
//! diagnostic.

use super::{diag_at, LintReport, Rule, Severity};
use crate::analysis::{ControlDeps, DefUse};
use crate::ir::{ChanId, ChanKind, Function, InstrId, Module, Op, Terminator, ValueId};
use std::collections::HashSet;

/// A monolithic (STA) function must carry no channel traffic at all.
pub fn check_no_channel_ops(m: &Module, f: &Function, r: &mut LintReport) {
    for b in &f.blocks {
        for &iid in &b.instrs {
            if matches!(
                f.instr(iid).op,
                Op::SendLdAddr { .. }
                    | Op::SendStAddr { .. }
                    | Op::ConsumeVal { .. }
                    | Op::ProduceVal { .. }
                    | Op::PoisonVal { .. }
            ) {
                r.push(diag_at(
                    Rule::Decouple,
                    Severity::Error,
                    m,
                    f,
                    iid,
                    "channel intrinsic in a monolithic (STA) function".into(),
                ));
            }
        }
    }
}

pub fn check_dae(p: &crate::transform::DaeProgram, r: &mut LintReport) {
    let m = &p.module;
    let agu = p.agu_fn();
    let cu = p.cu_fn();

    // -- op classes ---------------------------------------------------------
    for b in &agu.blocks {
        for &iid in &b.instrs {
            let bad: Option<&str> = match &agu.instr(iid).op {
                Op::Load { .. } | Op::Store { .. } => {
                    Some("raw memory op survived decoupling in the access slice")
                }
                Op::ProduceVal { .. } => Some("store value produced in the access slice"),
                Op::PoisonVal { .. } => Some("poison issued from the access slice"),
                Op::ConsumeVal { chan, .. } if m.chan(*chan).kind != ChanKind::LdValAgu => {
                    Some("access slice pops a CU-bound value channel")
                }
                _ => None,
            };
            if let Some(msg) = bad {
                r.push(diag_at(Rule::Decouple, Severity::Error, m, agu, iid, msg.into()));
            }
        }
    }
    for b in &cu.blocks {
        for &iid in &b.instrs {
            let bad: Option<&str> = match &cu.instr(iid).op {
                Op::Load { .. } | Op::Store { .. } => {
                    Some("raw memory op survived decoupling in the execute slice")
                }
                Op::SendLdAddr { .. } | Op::SendStAddr { .. } => {
                    Some("request traffic issued from the execute slice")
                }
                Op::ConsumeVal { chan, .. } if m.chan(*chan).kind != ChanKind::LdVal => {
                    Some("execute slice pops a non-ld_val channel")
                }
                Op::ProduceVal { chan, .. } | Op::PoisonVal { chan, .. }
                    if m.chan(*chan).kind != ChanKind::StVal =>
                {
                    Some("store value pushed on a non-st_val channel")
                }
                _ => None,
            };
            if let Some(msg) = bad {
                r.push(diag_at(Rule::Decouple, Severity::Error, m, cu, iid, msg.into()));
            }
        }
    }

    // -- double consumers ---------------------------------------------------
    // A FIFO has exactly one popper: the same (chan, mem) consumed in both
    // slices would race for elements.
    let consumed = |f: &Function| -> HashSet<(ChanId, u32)> {
        let mut s = HashSet::new();
        for b in &f.blocks {
            for &iid in &b.instrs {
                if let Op::ConsumeVal { chan, mem, .. } = f.instr(iid).op {
                    s.insert((chan, mem));
                }
            }
        }
        s
    };
    let agu_pops = consumed(agu);
    for b in &cu.blocks {
        for &iid in &b.instrs {
            if let Op::ConsumeVal { chan, mem, .. } = cu.instr(iid).op {
                if agu_pops.contains(&(chan, mem)) {
                    r.push(diag_at(
                        Rule::Decouple,
                        Severity::Error,
                        m,
                        cu,
                        iid,
                        format!("channel {chan}:m{mem} popped by both slices"),
                    ));
                }
            }
        }
    }

    // -- LoD attribution + dead consumes ------------------------------------
    let du = DefUse::new(agu);
    let cd = ControlDeps::new(agu);
    let mut consumes: Vec<(InstrId, ValueId, u32)> = Vec::new();
    for b in &agu.blocks {
        for &iid in &b.instrs {
            if let Op::ConsumeVal { mem, .. } = agu.instr(iid).op {
                if let Some(res) = agu.instr(iid).result {
                    consumes.push((iid, res, mem));
                }
            }
        }
    }
    for &(iid, res, mem) in &consumes {
        if du.users(res).is_empty() && du.term_users(res).is_empty() {
            r.push(diag_at(
                Rule::Decouple,
                Severity::Warn,
                m,
                agu,
                iid,
                format!("consumed LoD value m{mem} is never used — spurious blocking pop"),
            ));
        }
    }
    if !consumes.is_empty() {
        for (bi, b) in agu.blocks.iter().enumerate() {
            for &iid in &b.instrs {
                let (idx, mem) = match agu.instr(iid).op {
                    Op::SendLdAddr { idx, mem, .. } => (idx, mem),
                    Op::SendStAddr { idx, mem, .. } => (idx, mem),
                    _ => continue,
                };
                // Data slice of the address plus the conditions of every
                // branch the send's block is control-dependent on.
                let mut roots = vec![idx];
                for ctrl in cd.transitive(crate::ir::BlockId(bi as u32)) {
                    if let Terminator::CondBr { cond, .. } = agu.block(ctrl).term {
                        roots.push(cond);
                    }
                }
                let bslice: HashSet<InstrId> =
                    du.backward_slice(agu, &roots, true).into_iter().collect();
                for &(cid, _, cmem) in &consumes {
                    if bslice.contains(&cid) {
                        r.push(diag_at(
                            Rule::Decouple,
                            Severity::Info,
                            m,
                            agu,
                            iid,
                            format!(
                                "send for m{mem} depends on the consumed value of m{cmem} \
                                 (loss-of-decoupling chain)"
                            ),
                        ));
                    }
                }
            }
        }
    }
}
