//! Vectorised speculation — the paper's §10 future work, built as a
//! first-class runtime feature: the AGU side issues a *vector* of
//! speculative requests per batch, the XLA-compiled compute (L2 JAX + L1
//! Pallas, AOT'd to `artifacts/`) produces per-lane store values plus a
//! **store mask** (the vector analogue of the poison bit), and the DU
//! side applies a masked scatter.
//!
//! Correctness subtlety the scalar machine gets for free: within one
//! batch, gathered guard/operand values are stale with respect to
//! earlier lanes of the *same* batch (intra-batch RAW). Lanes whose
//! target address collides with any earlier lane in the batch are
//! detected and replayed serially — the vector unit's equivalent of an
//! LSQ hazard (reported in [`VectorSpecStats::conflict_lanes`]).

use super::client::{Executable, PjrtRuntime};
use anyhow::Result;

#[derive(Clone, Copy, Debug, Default)]
pub struct VectorSpecStats {
    pub batches: u64,
    pub lanes: u64,
    /// Lanes whose store was masked off (the vector "poison").
    pub masked_lanes: u64,
    /// Lanes replayed serially due to intra-batch address collisions.
    pub conflict_lanes: u64,
}

/// Engine wrapping one AOT-compiled step function.
pub struct VectorSpecEngine {
    exe: Executable,
    pub batch: usize,
    pub stats: VectorSpecStats,
}

impl VectorSpecEngine {
    pub fn new(rt: &PjrtRuntime, artifact: &str, batch: usize) -> Result<Self> {
        Ok(VectorSpecEngine {
            exe: rt.load_artifact(artifact)?,
            batch,
            stats: VectorSpecStats::default(),
        })
    }

    /// Vectorised `hist`: `if (H[d[i]] < CAP) H[d[i]] += 1` over all of
    /// `d`, batching the guarded update through the XLA step function
    /// (inputs: H, idx-batch; outputs: new values, keep mask).
    pub fn run_hist(&mut self, h: &mut [i64], d: &[i64], cap: i64) -> Result<()> {
        let b = self.batch;
        let mut i = 0;
        while i < d.len() {
            let hi = (i + b).min(d.len());
            let idx = &d[i..hi];
            // pad the final partial batch (the artifact has a fixed lane
            // count; padding lanes target a scratch replay below)
            let mut padded: Vec<i64> = idx.to_vec();
            padded.resize(b, -1);
            // intra-batch conflict detection: a lane colliding with any
            // earlier lane reads a stale gather — replay serially
            let mut conflict = vec![false; padded.len()];
            for l in 0..idx.len() {
                for e in 0..l {
                    if padded[e] == padded[l] {
                        conflict[l] = true;
                        break;
                    }
                }
            }
            // speculative vector request: gather+compute+mask via XLA
            let clamped: Vec<i64> =
                padded.iter().map(|&x| x.clamp(0, h.len() as i64 - 1)).collect();
            let outs = self.exe.run_i64(&[h, &clamped])?;
            let (vals, mask) = (&outs[0], &outs[1]);
            for l in 0..idx.len() {
                self.stats.lanes += 1;
                if conflict[l] {
                    // serial replay (vector-LSQ hazard)
                    self.stats.conflict_lanes += 1;
                    let t = idx[l] as usize;
                    if h[t] < cap {
                        h[t] += 1;
                    } else {
                        self.stats.masked_lanes += 1;
                    }
                } else if mask[l] != 0 {
                    h[idx[l] as usize] = vals[l];
                } else {
                    self.stats.masked_lanes += 1; // vector poison
                }
            }
            self.stats.batches += 1;
            i = hi;
        }
        Ok(())
    }

    /// Vectorised `thr`: zero R/G/B lanes whose sum exceeds the
    /// threshold; the mask output is the store mask for all three arrays.
    pub fn run_thr(
        &mut self,
        r: &mut [i64],
        g: &mut [i64],
        b_arr: &mut [i64],
    ) -> Result<()> {
        let b = self.batch;
        let n = r.len();
        let mut i = 0;
        while i < n {
            let hi = (i + b).min(n);
            let mut rr: Vec<i64> = r[i..hi].to_vec();
            let mut gg: Vec<i64> = g[i..hi].to_vec();
            let mut bb: Vec<i64> = b_arr[i..hi].to_vec();
            rr.resize(b, 0);
            gg.resize(b, 0);
            bb.resize(b, 0);
            let outs = self.exe.run_i64(&[&rr, &gg, &bb])?;
            let mask = &outs[0];
            for l in 0..(hi - i) {
                self.stats.lanes += 1;
                if mask[l] != 0 {
                    r[i + l] = 0;
                    g[i + l] = 0;
                    b_arr[i + l] = 0;
                } else {
                    self.stats.masked_lanes += 1;
                }
            }
            self.stats.batches += 1;
            i = hi;
        }
        Ok(())
    }
}
