//! Offline stand-in for the `xla` crate (PJRT bindings).
//!
//! The real crate is not vendored in the offline build; this shim
//! mirrors the subset of its API that [`super::client`] uses so that
//! `cargo build --features pjrt` compiles without network access.
//! Every entry point that would need a real PJRT runtime returns
//! [`XlaError`] explaining that the stub is active; pure data-shaping
//! helpers ([`Literal::vec1`], [`Literal::reshape`]) work for real. To
//! run against actual XLA, replace the `use ... xla_stub as xla` alias
//! in `client.rs` with the real crate (e.g. via a `[patch]` section).

pub struct XlaError(pub String);

impl XlaError {
    fn stub(what: &str) -> Self {
        XlaError(format!(
            "{what}: PJRT unavailable — built against the vendored xla stub \
             (offline build); link the real xla crate to execute artifacts"
        ))
    }
}

impl std::fmt::Debug for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

/// PJRT client handle. [`PjRtClient::cpu`] always fails in the stub.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(XlaError::stub("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::stub("PjRtClient::compile"))
    }
}

/// Parsed HLO module. Text parsing needs real XLA, so this always fails.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(XlaError::stub("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::stub("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::stub("PjRtBuffer::to_literal_sync"))
    }
}

/// Host-side literal. Construction and reshape are pure data shaping and
/// work for real; device round-trips fail like everything else.
#[derive(Clone)]
pub struct Literal {
    pub data: Vec<i64>,
    pub dims: Vec<i64>,
}

impl Literal {
    pub fn vec1(xs: &[i64]) -> Literal {
        Literal { data: xs.to_vec(), dims: vec![xs.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n != self.data.len() as i64 {
            return Err(XlaError(format!(
                "reshape: {} elements into shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(XlaError::stub("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(XlaError::stub("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shaping_works_and_runtime_entry_points_fail() {
        let l = Literal::vec1(&[1, 2, 3, 4]);
        assert_eq!(l.reshape(&[2, 2]).unwrap().dims, vec![2, 2]);
        assert!(l.reshape(&[3]).is_err());
        let err = PjRtClient::cpu().err().unwrap();
        assert!(format!("{err:?}").contains("PJRT unavailable"), "{err}");
    }
}
