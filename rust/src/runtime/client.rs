//! Thin wrapper over the `xla` crate: load HLO text produced by
//! `python/compile/aot.py`, compile once on the PJRT CPU client, execute
//! from the Rust hot path.
//!
//! Interchange is HLO **text**, not serialized `HloModuleProto`: jax
//! ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md).

use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

// Offline builds compile against the vendored stub; swap this alias for
// the real `xla` crate (via a [patch] section) to execute artifacts.
use crate::runtime::xla_stub as xla;

/// Locate the artifacts directory: `$DAE_SPEC_ARTIFACTS`, else
/// `<repo>/artifacts` relative to the current dir or its parents.
pub fn artifacts_dir() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("DAE_SPEC_ARTIFACTS") {
        let p = PathBuf::from(p);
        if p.is_dir() {
            return Some(p);
        }
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join("artifacts");
        if cand.is_dir() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}

/// A PJRT CPU client plus a cache of compiled executables.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

/// One compiled model variant.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {path:?}: {e:?}"))?;
        Ok(Executable {
            exe,
            name: path.file_stem().unwrap_or_default().to_string_lossy().into_owned(),
        })
    }

    /// Load an artifact by stem name (`<artifacts>/<name>.hlo.txt`).
    pub fn load_artifact(&self, name: &str) -> Result<Executable> {
        let dir = artifacts_dir()
            .ok_or_else(|| anyhow!("artifacts/ not found — run `make artifacts` first"))?;
        self.load_hlo_text(&dir.join(format!("{name}.hlo.txt")))
            .with_context(|| format!("loading artifact {name}"))
    }
}

impl Executable {
    /// Execute with i64 vector inputs; returns all outputs as i64 vectors
    /// (artifacts are lowered with `return_tuple=True`).
    pub fn run_i64(&self, inputs: &[&[i64]]) -> Result<Vec<Vec<i64>>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|x| {
                xla::Literal::vec1(x)
                    .reshape(&[x.len() as i64])
                    .map_err(|e| anyhow!("reshape: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e:?}"))?;
        let tuple = out.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        tuple
            .into_iter()
            .map(|l| l.to_vec::<i64>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Compile-and-run path is exercised end-to-end in
    /// `rust/tests/runtime.rs` (needs `make artifacts` plus the real
    /// xla crate); against the vendored stub, client bring-up must fail
    /// with an error that names the stub rather than e.g. panic.
    #[test]
    fn cpu_client_reports_stub_unavailable() {
        let err = PjrtRuntime::cpu().err().expect("stub client must not boot");
        assert!(format!("{err:#}").contains("PJRT"), "unexpected error: {err:#}");
    }
}
