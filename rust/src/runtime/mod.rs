//! PJRT-backed execution of AOT-compiled JAX/Pallas artifacts, and the
//! vectorised-speculation engine (paper §10 future work: "filling a
//! vector of speculative requests in the AGU and producing a store mask
//! in the CU").
//!
//! Python runs only at build time (`make artifacts` → `python/compile/`):
//! the L2 JAX models (calling the L1 Pallas kernels) are lowered once to
//! HLO *text* under `artifacts/`; this module loads and executes them via
//! the PJRT CPU client (`xla` crate). Nothing here imports Python.

pub mod client;
pub mod vector_spec;
pub mod xla_stub;

pub use client::{artifacts_dir, Executable, PjrtRuntime};
pub use vector_spec::{VectorSpecEngine, VectorSpecStats};
