//! Chrome/Perfetto `trace_event` JSON exporter.
//!
//! Converts a simulator [`Trace`](crate::sim::Trace) (plus, when
//! available, the [`Metrics`] collectors) into the JSON Object Format
//! of the `trace_event` specification, viewable at
//! <https://ui.perfetto.dev> (or `chrome://tracing`):
//!
//! - one thread lane per unit (`agu`, `cu`, `du`, `sta`), named via
//!   `"M"` metadata events;
//! - every pipeline event becomes a 1-cycle `"X"` complete event
//!   (`ts` is the cycle number, interpreted as microseconds — the
//!   `displayTimeUnit` hint keeps the axis readable);
//! - poison events become `"i"` instant events with thread scope, so
//!   mis-speculation shows up as markers over the CU/DU lanes;
//! - channel occupancy and per-array decoupling-slack/in-flight
//!   [`CounterTrack`](super::CounterTrack)s become `"C"` counter
//!   events.
//!
//! Output is deterministic: lanes are ordered by first appearance,
//! events are stably sorted by timestamp, and all JSON keys are
//! insertion-ordered — same run, byte-identical document.

use super::Metrics;
use crate::sim::TraceEvent;
use crate::util::Json;

const PID: f64 = 1.0;

fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn counter(name: &str, series: &str, t: u64, v: i64) -> Json {
    Json::Obj(vec![
        ("name".into(), s(name)),
        ("ph".into(), s("C")),
        ("ts".into(), Json::Num(t as f64)),
        ("pid".into(), Json::Num(PID)),
        ("args".into(), Json::Obj(vec![(series.to_string(), Json::Num(v as f64))])),
    ])
}

/// Build the `trace_event` document for one run. `metrics` adds the
/// counter tracks; `chan_names`/`array_names` resolve track labels.
pub fn export(
    label: &str,
    events: &[TraceEvent],
    metrics: Option<&Metrics>,
    chan_names: &[String],
    array_names: &[String],
) -> Json {
    let mut out: Vec<Json> = Vec::new();
    out.push(Json::Obj(vec![
        ("name".into(), s("process_name")),
        ("ph".into(), s("M")),
        ("pid".into(), Json::Num(PID)),
        ("args".into(), Json::Obj(vec![("name".into(), s(label))])),
    ]));

    // one lane (tid) per unit, ordered by first appearance
    let mut lanes: Vec<&'static str> = Vec::new();
    for e in events {
        if !lanes.contains(&e.unit) {
            lanes.push(e.unit);
        }
    }
    for (i, unit) in lanes.iter().enumerate() {
        out.push(Json::Obj(vec![
            ("name".into(), s("thread_name")),
            ("ph".into(), s("M")),
            ("pid".into(), Json::Num(PID)),
            ("tid".into(), Json::Num((i + 1) as f64)),
            ("args".into(), Json::Obj(vec![("name".into(), s(unit))])),
        ]));
    }

    let mut body: Vec<(u64, Json)> = Vec::with_capacity(events.len());
    for e in events {
        let tid = (lanes.iter().position(|u| *u == e.unit).unwrap() + 1) as f64;
        let name = format!("{} m{}", e.kind, e.mem);
        let obj = if e.kind.contains("poison") {
            Json::Obj(vec![
                ("name".into(), Json::Str(name)),
                ("cat".into(), s("poison")),
                ("ph".into(), s("i")),
                ("s".into(), s("t")),
                ("ts".into(), Json::Num(e.t as f64)),
                ("pid".into(), Json::Num(PID)),
                ("tid".into(), Json::Num(tid)),
            ])
        } else {
            Json::Obj(vec![
                ("name".into(), Json::Str(name)),
                ("cat".into(), s(e.kind)),
                ("ph".into(), s("X")),
                ("ts".into(), Json::Num(e.t as f64)),
                ("dur".into(), Json::Num(1.0)),
                ("pid".into(), Json::Num(PID)),
                ("tid".into(), Json::Num(tid)),
            ])
        };
        body.push((e.t, obj));
    }

    if let Some(m) = metrics {
        for (i, cm) in m.chans.iter().enumerate() {
            let name = format!("occupancy {}", chan_names[i]);
            for &(t, v) in cm.occ_track.samples() {
                body.push((t, counter(&name, "elems", t, v)));
            }
        }
        for (i, sm) in m.slack.iter().enumerate() {
            let sname = format!("slack @{}", array_names[i]);
            for &(t, v) in sm.slack_track.samples() {
                body.push((t, counter(&sname, "cycles", t, v)));
            }
            let iname = format!("in-flight @{}", array_names[i]);
            for &(t, v) in sm.inflight_track.samples() {
                body.push((t, counter(&iname, "reqs", t, v)));
            }
        }
    }

    // Perfetto tolerates unsorted streams; sorting (stably) makes the
    // document deterministic and diff-friendly.
    body.sort_by_key(|(t, _)| *t);
    out.extend(body.into_iter().map(|(_, j)| j));

    Json::Obj(vec![
        ("traceEvents".into(), Json::Arr(out)),
        ("displayTimeUnit".into(), s("ns")),
    ])
}
