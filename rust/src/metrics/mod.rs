//! Decoupling telemetry — the observability layer of the simulator.
//!
//! The timing model in [`crate::sim`] is a timestamp-dataflow machine:
//! every cycle number is computed from data dependencies, never from
//! host scheduling. That makes observation safe by construction — the
//! collectors in this module only *read* what the machine was going to
//! do anyway, so enabling them (`MachineConfig::metrics`) leaves
//! cycles, memory and commit logs bit-identical (pinned by
//! `rust/tests/metrics.rs`). With metrics off the hooks compile to a
//! single `Option` discriminant test on cold paths.
//!
//! What is measured:
//!
//! - **Per-unit cycle accounting** — busy (dynamic instructions),
//!   blocked-on-pop (cycles a consumer idled waiting for data,
//!   attributed per channel), blocked-on-push (events where a full
//!   FIFO parked its producer) and an idle estimate
//!   (`cycles − busy − blocked_pop`, saturating).
//! - **Per-channel occupancy** — log2-bucketed occupancy histogram
//!   sampled at every push, high-water mark, push/pop/poison counts
//!   and a decimated occupancy [`CounterTrack`] for trace export.
//! - **LSQ fill/residency** — admissions by kind, window high-water
//!   mark, mean residency (admission → commit/poison/load-done) and
//!   the cycles of mis-speculated work discarded by poisons.
//! - **Speculation counters** — speculatively hoisted store/load
//!   requests issued, poisons produced, and the poison rate, total and
//!   per array.
//! - **Decoupling slack** — the paper-level derived metric: how far
//!   the AGU runs ahead of the CU, measured at every Lemma 6.1 store
//!   pairing as `t(value arrival) − t(request arrival)` in cycles,
//!   plus the in-flight request count (LSQ window occupancy) at that
//!   moment; min/mean/max and sampled tracks per array.
//! - **MLP** — mean outstanding loads: the sum of all load latencies
//!   divided by total cycles (a load occupying the memory system for
//!   `l` cycles contributes `l` cycle-slots of parallelism).
//!
//! Surfaces: `dae-spec profile` (human report + `--json`), the
//! Chrome/Perfetto exporter in [`perfetto`] (open the written JSON at
//! <https://ui.perfetto.dev>), and the `MetricsSummary` embedded per
//! cell in `BENCH_sim.json` (schema `dae-spec-bench/v3`).

pub mod perfetto;

use crate::util::Json;

/// Number of log2 occupancy-histogram buckets: 0, 1, 2-3, 4-7, 8-15,
/// 16-31, 32-63, 64+.
pub const OCC_BUCKETS: usize = 8;

/// Retained-sample cap per [`CounterTrack`] before decimation.
const TRACK_CAP: usize = 2048;

#[inline]
fn occ_bucket(occ: usize) -> usize {
    if occ == 0 {
        0
    } else {
        ((usize::BITS - occ.leading_zeros()) as usize).min(OCC_BUCKETS - 1)
    }
}

/// Human label of occupancy-histogram bucket `i`.
pub fn occ_bucket_label(i: usize) -> &'static str {
    ["0", "1", "2-3", "4-7", "8-15", "16-31", "32-63", "64+"][i]
}

/// A bounded, deterministically decimated time series of counter
/// samples for trace export ("sampled per N cycles" with adaptive N).
///
/// Every offered sample is counted; only every `stride`-th is
/// retained. When the retained set reaches [`TRACK_CAP`] it is thinned
/// to every other sample and the stride doubles — so the memory bound
/// is fixed, and because decimation is driven by the sample *index*
/// (never by host time), the retained set is a pure function of the
/// offered sequence.
#[derive(Clone, Debug, PartialEq)]
pub struct CounterTrack {
    samples: Vec<(u64, i64)>,
    stride: u64,
    idx: u64,
}

impl Default for CounterTrack {
    fn default() -> Self {
        CounterTrack { samples: Vec::new(), stride: 1, idx: 0 }
    }
}

impl CounterTrack {
    pub fn reset(&mut self) {
        self.samples.clear();
        self.stride = 1;
        self.idx = 0;
    }

    /// Offer a sample: value `v` observed at cycle `t`.
    #[inline]
    pub fn push(&mut self, t: u64, v: i64) {
        if self.idx % self.stride == 0 {
            self.samples.push((t, v));
            if self.samples.len() >= TRACK_CAP {
                let mut w = 0;
                for r in (0..self.samples.len()).step_by(2) {
                    self.samples[w] = self.samples[r];
                    w += 1;
                }
                self.samples.truncate(w);
                self.stride *= 2;
            }
        }
        self.idx += 1;
    }

    /// Retained `(cycle, value)` samples, in offer order.
    pub fn samples(&self) -> &[(u64, i64)] {
        &self.samples
    }

    /// Current decimation stride (1 = every offered sample retained).
    pub fn stride(&self) -> u64 {
        self.stride
    }
}

/// Raw per-channel collectors.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ChanMetrics {
    pub pushes: u64,
    pub pops: u64,
    pub poison_pushes: u64,
    /// High-water occupancy (elements queued right after a push).
    pub hwm: usize,
    /// Log2-bucketed occupancy histogram, sampled at every push.
    pub occ_hist: [u64; OCC_BUCKETS],
    /// Events where a full FIFO parked its producer (functional
    /// backpressure; counted once per parking, not per retry).
    pub producer_blocks: u64,
    /// Cycles the consumer spent waiting for data to arrive.
    pub consumer_wait_cycles: u64,
    pub occ_track: CounterTrack,
}

/// Raw per-array LSQ collectors.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LsqMetrics {
    pub admitted_loads: u64,
    pub admitted_stores: u64,
    pub commits: u64,
    pub poisons: u64,
    /// High-water window occupancy at admission.
    pub window_hwm: usize,
    /// Total residency (admission → commit / poison / load-done).
    pub residency_sum: u64,
    /// Residency of poisoned (discarded) store requests only.
    pub poison_residency_sum: u64,
    /// Requests that left the window (denominator of mean residency).
    pub resolved: u64,
}

/// Raw per-array decoupling-slack collectors, sampled at every
/// Lemma 6.1 store pairing in the DU.
#[derive(Clone, Debug, PartialEq)]
pub struct SlackMetrics {
    pub pairings: u64,
    /// Signed slack sum: `t(value) − t(request)` per pairing.
    pub slack_sum: i64,
    pub slack_min: i64,
    pub slack_max: i64,
    /// LSQ window occupancy (in-flight requests) at each pairing.
    pub inflight_sum: u64,
    pub inflight_max: usize,
    pub slack_track: CounterTrack,
    pub inflight_track: CounterTrack,
}

impl Default for SlackMetrics {
    fn default() -> Self {
        SlackMetrics {
            pairings: 0,
            slack_sum: 0,
            slack_min: i64::MAX,
            slack_max: i64::MIN,
            inflight_sum: 0,
            inflight_max: 0,
            slack_track: CounterTrack::default(),
            inflight_track: CounterTrack::default(),
        }
    }
}

/// All raw collectors of one run. Owned by `SimSession`, threaded
/// through the machine as `&mut Option<Metrics>` so that `None`
/// (metrics off) costs one discriminant test per hook site.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Metrics {
    pub chans: Vec<ChanMetrics>,
    pub lsqs: Vec<LsqMetrics>,
    pub slack: Vec<SlackMetrics>,
    /// Loads issued to memory (STA ports and DU LSQ alike).
    pub loads_issued: u64,
    /// Sum of load latencies — MLP numerator.
    pub load_lat_sum: u64,
}

impl Metrics {
    pub fn new(n_chans: usize, n_arrays: usize) -> Metrics {
        Metrics {
            chans: vec![ChanMetrics::default(); n_chans],
            lsqs: vec![LsqMetrics::default(); n_arrays],
            slack: vec![SlackMetrics::default(); n_arrays],
            loads_issued: 0,
            load_lat_sum: 0,
        }
    }

    /// Reset all counters in place (capacity retained) — run on entry
    /// by `SimSession::run`, so a failed run never leaks counts into
    /// the next one.
    pub fn reset(&mut self) {
        for c in &mut self.chans {
            let occ_track = std::mem::take(&mut c.occ_track);
            *c = ChanMetrics { occ_track, ..ChanMetrics::default() };
            c.occ_track.reset();
        }
        for l in &mut self.lsqs {
            *l = LsqMetrics::default();
        }
        for s in &mut self.slack {
            let slack_track = std::mem::take(&mut s.slack_track);
            let inflight_track = std::mem::take(&mut s.inflight_track);
            *s = SlackMetrics { slack_track, inflight_track, ..SlackMetrics::default() };
            s.slack_track.reset();
            s.inflight_track.reset();
        }
        self.loads_issued = 0;
        self.load_lat_sum = 0;
    }

    /// A push of arrival time `t` completed; `occ` is the occupancy
    /// right after it.
    #[inline]
    pub fn on_push(&mut self, chan: u32, occ: usize, t: u64, poison: bool) {
        let c = &mut self.chans[chan as usize];
        c.pushes += 1;
        if poison {
            c.poison_pushes += 1;
        }
        c.hwm = c.hwm.max(occ);
        c.occ_hist[occ_bucket(occ)] += 1;
        c.occ_track.push(t, occ as i64);
    }

    /// A full FIFO parked its producer.
    #[inline]
    pub fn on_push_blocked(&mut self, chan: u32) {
        self.chans[chan as usize].producer_blocks += 1;
    }

    /// A pop completed; `occ` is the occupancy right after it, `wait`
    /// the cycles the consumer idled for the element to arrive.
    #[inline]
    pub fn on_pop(&mut self, chan: u32, occ: usize, t: u64, wait: u64) {
        let c = &mut self.chans[chan as usize];
        c.pops += 1;
        c.consumer_wait_cycles += wait;
        c.occ_track.push(t, occ as i64);
    }

    /// A request entered an LSQ window (`window` = occupancy after).
    #[inline]
    pub fn on_admit(&mut self, arr: u32, is_store: bool, window: usize) {
        let l = &mut self.lsqs[arr as usize];
        if is_store {
            l.admitted_stores += 1;
        } else {
            l.admitted_loads += 1;
        }
        l.window_hwm = l.window_hwm.max(window);
    }

    /// A store request paired with its value (Lemma 6.1 rendezvous):
    /// the decoupling-slack sample point.
    #[inline]
    pub fn on_store_pair(&mut self, arr: u32, t_req: u64, t_val: u64, inflight: usize) {
        let s = &mut self.slack[arr as usize];
        let slack = t_val as i64 - t_req as i64;
        s.pairings += 1;
        s.slack_sum += slack;
        s.slack_min = s.slack_min.min(slack);
        s.slack_max = s.slack_max.max(slack);
        s.inflight_sum += inflight as u64;
        s.inflight_max = s.inflight_max.max(inflight);
        s.slack_track.push(t_val, slack);
        s.inflight_track.push(t_val, inflight as i64);
    }

    /// A store committed after `residency` cycles in the window.
    #[inline]
    pub fn on_store_commit(&mut self, arr: u32, residency: u64) {
        let l = &mut self.lsqs[arr as usize];
        l.commits += 1;
        l.residency_sum += residency;
        l.resolved += 1;
    }

    /// A poisoned store was discarded after `residency` cycles — that
    /// residency is the mis-speculated work thrown away.
    #[inline]
    pub fn on_store_poison(&mut self, arr: u32, residency: u64) {
        let l = &mut self.lsqs[arr as usize];
        l.poisons += 1;
        l.residency_sum += residency;
        l.poison_residency_sum += residency;
        l.resolved += 1;
    }

    /// A load occupied the memory system for `lat` cycles (MLP).
    #[inline]
    pub fn on_load_issue(&mut self, lat: u64) {
        self.loads_issued += 1;
        self.load_lat_sum += lat;
    }

    /// A load left an LSQ window after `residency` cycles.
    #[inline]
    pub fn on_load_done(&mut self, arr: u32, residency: u64) {
        let l = &mut self.lsqs[arr as usize];
        l.residency_sum += residency;
        l.resolved += 1;
    }
}

/// Static producer/consumer unit of a channel — known from the channel
/// kind, so blocked cycles attribute per unit without runtime ids.
#[derive(Clone, Copy, Debug)]
pub struct ChanRole {
    pub producer: &'static str,
    pub consumer: &'static str,
}

/// Everything `Metrics::summarize` needs that the collectors don't
/// carry themselves: names, roles, run length and per-mem statistics.
pub struct SummaryEnv<'a> {
    pub cycles: u64,
    /// `(unit name, dynamic instructions)` per stepped unit.
    pub units: &'a [(String, u64)],
    pub chan_names: Vec<String>,
    pub chan_roles: Vec<ChanRole>,
    pub array_names: Vec<String>,
    /// Dense per mem-op `(requests, poisons)`.
    pub per_mem: &'a [(u64, u64)],
    /// Static mem-op ids speculatively hoisted as stores / loads
    /// (SPEC builds; empty otherwise).
    pub spec_store_mems: &'a [u32],
    pub spec_load_mems: &'a [u32],
}

#[derive(Clone, Debug, PartialEq)]
pub struct UnitSummary {
    pub unit: String,
    /// Dynamic instructions executed (busy cycles).
    pub busy_instrs: u64,
    /// Cycles spent waiting for channel data, summed over channels.
    pub blocked_pop_cycles: u64,
    /// Times a full FIFO parked this unit as producer.
    pub blocked_push_events: u64,
    /// `cycles − busy − blocked_pop`, saturating — an estimate, since
    /// busy and blocked can overlap in a dataflow timing model.
    pub idle_cycles_est: u64,
    /// Blocked-on-pop attribution: `(channel name, cycles)`, nonzero
    /// entries only.
    pub blocked_by: Vec<(String, u64)>,
}

#[derive(Clone, Debug, PartialEq)]
pub struct ChanSummary {
    pub name: String,
    pub producer: String,
    pub consumer: String,
    pub pushes: u64,
    pub pops: u64,
    pub poison_pushes: u64,
    pub hwm: usize,
    pub occ_hist: [u64; OCC_BUCKETS],
    pub producer_blocks: u64,
    pub consumer_wait_cycles: u64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct LsqSummary {
    pub array: String,
    pub admitted_loads: u64,
    pub admitted_stores: u64,
    pub commits: u64,
    pub poisons: u64,
    pub window_hwm: usize,
    pub mean_residency: f64,
    /// Cycles of mis-speculated store residency discarded by poisons.
    pub discarded_cycles: u64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct SlackSummary {
    pub array: String,
    pub pairings: u64,
    /// Mean AGU lead over the CU in cycles (positive = AGU ahead).
    pub mean_slack: f64,
    pub min_slack: i64,
    pub max_slack: i64,
    /// Mean in-flight requests in the LSQ window at pairing time.
    pub mean_inflight: f64,
    pub max_inflight: usize,
}

#[derive(Clone, Debug, PartialEq)]
pub struct SpecArraySummary {
    pub array: String,
    /// Store requests admitted for this array (SPEC: all speculated).
    pub store_reqs: u64,
    pub poisons: u64,
    pub poison_rate: f64,
}

#[derive(Clone, Debug, Default, PartialEq)]
pub struct SpecSummary {
    /// Requests issued by speculatively hoisted stores / loads.
    pub spec_store_reqs: u64,
    pub spec_load_reqs: u64,
    pub poisons: u64,
    /// Σ residency of poisoned stores — mis-speculated work discarded.
    pub discarded_cycles: u64,
    /// `poisons / spec_store_reqs`.
    pub poison_rate: f64,
    pub per_array: Vec<SpecArraySummary>,
}

/// The folded, name-resolved summary of one run — what `profile`
/// prints, `BENCH_sim.json` embeds and `StallDiagnostic` snapshots.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricsSummary {
    pub cycles: u64,
    /// Mean outstanding loads (Σ load latency / cycles).
    pub mlp: f64,
    pub loads_issued: u64,
    pub units: Vec<UnitSummary>,
    pub channels: Vec<ChanSummary>,
    pub lsqs: Vec<LsqSummary>,
    pub slack: Vec<SlackSummary>,
    pub speculation: SpecSummary,
}

impl Metrics {
    /// Fold the raw collectors into a [`MetricsSummary`].
    pub fn summarize(&self, env: &SummaryEnv) -> MetricsSummary {
        let units = env
            .units
            .iter()
            .map(|(name, instrs)| {
                let mut blocked_by: Vec<(String, u64)> = Vec::new();
                let mut blocked_pop = 0u64;
                let mut blocked_push = 0u64;
                for (i, c) in self.chans.iter().enumerate() {
                    let role = env.chan_roles[i];
                    if role.consumer == name.as_str() && c.consumer_wait_cycles > 0 {
                        blocked_by.push((env.chan_names[i].clone(), c.consumer_wait_cycles));
                        blocked_pop += c.consumer_wait_cycles;
                    }
                    if role.producer == name.as_str() {
                        blocked_push += c.producer_blocks;
                    }
                }
                UnitSummary {
                    unit: name.clone(),
                    busy_instrs: *instrs,
                    blocked_pop_cycles: blocked_pop,
                    blocked_push_events: blocked_push,
                    idle_cycles_est: env.cycles.saturating_sub(*instrs + blocked_pop),
                    blocked_by,
                }
            })
            .collect();

        let channels = self
            .chans
            .iter()
            .enumerate()
            .filter(|(_, c)| c.pushes + c.pops + c.producer_blocks > 0)
            .map(|(i, c)| ChanSummary {
                name: env.chan_names[i].clone(),
                producer: env.chan_roles[i].producer.to_string(),
                consumer: env.chan_roles[i].consumer.to_string(),
                pushes: c.pushes,
                pops: c.pops,
                poison_pushes: c.poison_pushes,
                hwm: c.hwm,
                occ_hist: c.occ_hist,
                producer_blocks: c.producer_blocks,
                consumer_wait_cycles: c.consumer_wait_cycles,
            })
            .collect();

        let lsqs: Vec<LsqSummary> = self
            .lsqs
            .iter()
            .enumerate()
            .filter(|(_, l)| l.admitted_loads + l.admitted_stores > 0)
            .map(|(i, l)| LsqSummary {
                array: env.array_names[i].clone(),
                admitted_loads: l.admitted_loads,
                admitted_stores: l.admitted_stores,
                commits: l.commits,
                poisons: l.poisons,
                window_hwm: l.window_hwm,
                mean_residency: ratio(l.residency_sum, l.resolved),
                discarded_cycles: l.poison_residency_sum,
            })
            .collect();

        let slack = self
            .slack
            .iter()
            .enumerate()
            .filter(|(_, s)| s.pairings > 0)
            .map(|(i, s)| SlackSummary {
                array: env.array_names[i].clone(),
                pairings: s.pairings,
                mean_slack: s.slack_sum as f64 / s.pairings as f64,
                min_slack: s.slack_min,
                max_slack: s.slack_max,
                mean_inflight: ratio(s.inflight_sum, s.pairings),
                max_inflight: s.inflight_max,
            })
            .collect();

        let sum_mems = |mems: &[u32], which: fn(&(u64, u64)) -> u64| -> u64 {
            mems.iter()
                .filter_map(|&m| env.per_mem.get(m as usize))
                .map(which)
                .sum()
        };
        let spec_store_reqs = sum_mems(env.spec_store_mems, |p| p.0);
        let spec_load_reqs = sum_mems(env.spec_load_mems, |p| p.0);
        let poisons: u64 = self.lsqs.iter().map(|l| l.poisons).sum();
        let per_array = self
            .lsqs
            .iter()
            .enumerate()
            .filter(|(_, l)| l.poisons > 0)
            .map(|(i, l)| SpecArraySummary {
                array: env.array_names[i].clone(),
                store_reqs: l.admitted_stores,
                poisons: l.poisons,
                poison_rate: ratio(l.poisons, l.admitted_stores),
            })
            .collect();
        let speculation = SpecSummary {
            spec_store_reqs,
            spec_load_reqs,
            poisons,
            discarded_cycles: self.lsqs.iter().map(|l| l.poison_residency_sum).sum(),
            poison_rate: ratio(poisons, spec_store_reqs),
            per_array,
        };

        MetricsSummary {
            cycles: env.cycles,
            mlp: ratio(self.load_lat_sum, env.cycles),
            loads_issued: self.loads_issued,
            units,
            channels,
            lsqs,
            slack,
            speculation,
        }
    }
}

fn ratio(num: u64, den: u64) -> f64 {
    if den == 0 {
        0.0
    } else {
        num as f64 / den as f64
    }
}

fn num(v: u64) -> Json {
    Json::Num(v as f64)
}

impl MetricsSummary {
    /// Machine-readable form, rendered via [`crate::util::Json`] —
    /// insertion-ordered keys, so same collectors → byte-identical
    /// output.
    pub fn to_json(&self) -> Json {
        let units = self
            .units
            .iter()
            .map(|u| {
                Json::Obj(vec![
                    ("unit".into(), Json::Str(u.unit.clone())),
                    ("busy_instrs".into(), num(u.busy_instrs)),
                    ("blocked_pop_cycles".into(), num(u.blocked_pop_cycles)),
                    ("blocked_push_events".into(), num(u.blocked_push_events)),
                    ("idle_cycles_est".into(), num(u.idle_cycles_est)),
                    (
                        "blocked_by".into(),
                        Json::Obj(
                            u.blocked_by
                                .iter()
                                .map(|(chan, cyc)| (chan.clone(), num(*cyc)))
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        let channels = self
            .channels
            .iter()
            .map(|c| {
                Json::Obj(vec![
                    ("name".into(), Json::Str(c.name.clone())),
                    ("producer".into(), Json::Str(c.producer.clone())),
                    ("consumer".into(), Json::Str(c.consumer.clone())),
                    ("pushes".into(), num(c.pushes)),
                    ("pops".into(), num(c.pops)),
                    ("poison_pushes".into(), num(c.poison_pushes)),
                    ("hwm".into(), num(c.hwm as u64)),
                    (
                        "occ_hist".into(),
                        Json::Arr(c.occ_hist.iter().map(|&v| num(v)).collect()),
                    ),
                    ("producer_blocks".into(), num(c.producer_blocks)),
                    ("consumer_wait_cycles".into(), num(c.consumer_wait_cycles)),
                ])
            })
            .collect();
        let lsqs = self
            .lsqs
            .iter()
            .map(|l| {
                Json::Obj(vec![
                    ("array".into(), Json::Str(l.array.clone())),
                    ("admitted_loads".into(), num(l.admitted_loads)),
                    ("admitted_stores".into(), num(l.admitted_stores)),
                    ("commits".into(), num(l.commits)),
                    ("poisons".into(), num(l.poisons)),
                    ("window_hwm".into(), num(l.window_hwm as u64)),
                    ("mean_residency".into(), Json::Num(l.mean_residency)),
                    ("discarded_cycles".into(), num(l.discarded_cycles)),
                ])
            })
            .collect();
        let slack = self
            .slack
            .iter()
            .map(|s| {
                Json::Obj(vec![
                    ("array".into(), Json::Str(s.array.clone())),
                    ("pairings".into(), num(s.pairings)),
                    ("mean_slack".into(), Json::Num(s.mean_slack)),
                    ("min_slack".into(), Json::Num(s.min_slack as f64)),
                    ("max_slack".into(), Json::Num(s.max_slack as f64)),
                    ("mean_inflight".into(), Json::Num(s.mean_inflight)),
                    ("max_inflight".into(), num(s.max_inflight as u64)),
                ])
            })
            .collect();
        let spec = &self.speculation;
        let per_array = spec
            .per_array
            .iter()
            .map(|a| {
                Json::Obj(vec![
                    ("array".into(), Json::Str(a.array.clone())),
                    ("store_reqs".into(), num(a.store_reqs)),
                    ("poisons".into(), num(a.poisons)),
                    ("poison_rate".into(), Json::Num(a.poison_rate)),
                ])
            })
            .collect();
        Json::Obj(vec![
            ("cycles".into(), num(self.cycles)),
            ("mlp".into(), Json::Num(self.mlp)),
            ("loads_issued".into(), num(self.loads_issued)),
            ("units".into(), Json::Arr(units)),
            ("channels".into(), Json::Arr(channels)),
            ("lsqs".into(), Json::Arr(lsqs)),
            ("slack".into(), Json::Arr(slack)),
            (
                "speculation".into(),
                Json::Obj(vec![
                    ("spec_store_reqs".into(), num(spec.spec_store_reqs)),
                    ("spec_load_reqs".into(), num(spec.spec_load_reqs)),
                    ("poisons".into(), num(spec.poisons)),
                    ("discarded_cycles".into(), num(spec.discarded_cycles)),
                    ("poison_rate".into(), Json::Num(spec.poison_rate)),
                    ("per_array".into(), Json::Arr(per_array)),
                ]),
            ),
        ])
    }

    /// Human-readable report (what `dae-spec profile` prints).
    pub fn render(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let _ = writeln!(s, "cycles: {}   mlp: {:.3}   loads issued: {}", self.cycles, self.mlp, self.loads_issued);
        let _ = writeln!(s, "units:");
        for u in &self.units {
            let _ = writeln!(
                s,
                "  {:<4} busy={:<10} blocked-pop={:<10} push-blocks={:<6} idle~{}",
                u.unit, u.busy_instrs, u.blocked_pop_cycles, u.blocked_push_events, u.idle_cycles_est
            );
            for (chan, cyc) in &u.blocked_by {
                let _ = writeln!(s, "       waited {cyc:>10} cycle(s) on {chan}");
            }
        }
        if !self.channels.is_empty() {
            let _ = writeln!(s, "channels:");
            for c in &self.channels {
                let _ = writeln!(
                    s,
                    "  {:<24} {}->{}  push={} pop={} poison={} hwm={} prod-blocks={}",
                    c.name, c.producer, c.consumer, c.pushes, c.pops, c.poison_pushes, c.hwm, c.producer_blocks
                );
                let hist: Vec<String> = c
                    .occ_hist
                    .iter()
                    .enumerate()
                    .filter(|(_, v)| **v > 0)
                    .map(|(i, v)| format!("{}:{v}", occ_bucket_label(i)))
                    .collect();
                let _ = writeln!(s, "       occupancy {{{}}}", hist.join(" "));
            }
        }
        if !self.lsqs.is_empty() {
            let _ = writeln!(s, "lsqs:");
            for l in &self.lsqs {
                let _ = writeln!(
                    s,
                    "  @{:<10} loads={} stores={} commits={} poisons={} hwm={} residency~{:.1} discarded={}",
                    l.array, l.admitted_loads, l.admitted_stores, l.commits, l.poisons, l.window_hwm,
                    l.mean_residency, l.discarded_cycles
                );
            }
        }
        if !self.slack.is_empty() {
            let _ = writeln!(s, "decoupling slack (AGU lead over CU, cycles):");
            for sl in &self.slack {
                let _ = writeln!(
                    s,
                    "  @{:<10} pairings={} mean={:.1} min={} max={} inflight mean={:.1} max={}",
                    sl.array, sl.pairings, sl.mean_slack, sl.min_slack, sl.max_slack, sl.mean_inflight,
                    sl.max_inflight
                );
            }
        }
        let sp = &self.speculation;
        let _ = writeln!(
            s,
            "speculation: store-reqs={} load-reqs={} poisons={} rate={:.4} discarded={} cycle(s)",
            sp.spec_store_reqs, sp.spec_load_reqs, sp.poisons, sp.poison_rate, sp.discarded_cycles
        );
        for a in &sp.per_array {
            let _ = writeln!(s, "  @{:<10} store-reqs={} poisons={} rate={:.4}", a.array, a.store_reqs, a.poisons, a.poison_rate);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_track_decimates_deterministically() {
        let mut a = CounterTrack::default();
        let mut b = CounterTrack::default();
        for i in 0..100_000u64 {
            a.push(i, i as i64);
            b.push(i, i as i64);
        }
        assert_eq!(a, b);
        assert!(a.samples().len() < TRACK_CAP);
        assert!(a.stride() > 1);
        // retained samples are a subsequence of the offered one
        let mut last = None;
        for &(t, v) in a.samples() {
            assert_eq!(t as i64, v);
            if let Some(p) = last {
                assert!(t > p);
            }
            last = Some(t);
        }
        // first offered sample always survives decimation
        assert_eq!(a.samples()[0], (0, 0));
    }

    #[test]
    fn counter_track_reset_restores_fresh_state() {
        let mut t = CounterTrack::default();
        for i in 0..10_000u64 {
            t.push(i, 1);
        }
        t.reset();
        assert_eq!(t, CounterTrack::default());
    }

    #[test]
    fn occ_buckets_cover_the_range() {
        assert_eq!(occ_bucket(0), 0);
        assert_eq!(occ_bucket(1), 1);
        assert_eq!(occ_bucket(2), 2);
        assert_eq!(occ_bucket(3), 2);
        assert_eq!(occ_bucket(4), 3);
        assert_eq!(occ_bucket(7), 3);
        assert_eq!(occ_bucket(63), 6);
        assert_eq!(occ_bucket(64), 7);
        assert_eq!(occ_bucket(usize::MAX), 7);
    }

    #[test]
    fn reset_clears_all_counters() {
        let mut m = Metrics::new(2, 1);
        m.on_push(0, 1, 5, true);
        m.on_pop(0, 0, 6, 2);
        m.on_push_blocked(1);
        m.on_admit(0, true, 1);
        m.on_store_pair(0, 5, 9, 1);
        m.on_store_poison(0, 4);
        m.on_load_issue(3);
        m.on_load_done(0, 2);
        m.reset();
        assert_eq!(m, Metrics::new(2, 1));
    }
}
