//! Ergonomic function construction, used by the workload definitions and
//! by tests.
//!
//! ```no_run
//! use dae_spec::ir::{FunctionBuilder, Module, Type, BinOp, CmpOp};
//!
//! let mut m = Module::new();
//! let a = m.add_array("A", Type::I64, 16);
//! let mut b = FunctionBuilder::new("inc_all");
//! let n = b.param("n", Type::I64);
//! let (entry, header, body, exit) = (b.block("entry"), b.block("header"), b.block("body"), b.block("exit"));
//! b.switch_to(entry);
//! let zero = b.const_i(0);
//! b.br(header);
//! b.switch_to(header);
//! let i = b.phi(Type::I64);
//! let c = b.icmp(CmpOp::Lt, i, n);
//! b.cond_br(c, body, exit);
//! b.switch_to(body);
//! let v = b.load(a, i, Type::I64);
//! let one = b.const_i(1);
//! let v2 = b.ibin(BinOp::Add, v, one);
//! b.store(a, i, v2);
//! let inext = b.ibin(BinOp::Add, i, one);
//! b.br(header);
//! b.switch_to(exit);
//! b.ret();
//! b.set_phi_incomings(i, vec![(entry, zero), (body, inext)]);
//! m.funcs.push(b.finish());
//! ```

use super::ops::{BinOp, CmpOp, Op, Terminator};
use super::types::Type;
use super::{ArrayId, BlockId, ChanId, Function, InstrId, ValueDef, ValueId};

pub struct FunctionBuilder {
    func: Function,
    cur: Option<BlockId>,
}

impl FunctionBuilder {
    pub fn new(name: &str) -> Self {
        FunctionBuilder { func: Function::new(name), cur: None }
    }

    pub fn param(&mut self, name: &str, ty: Type) -> ValueId {
        self.func.add_param(name, ty)
    }

    pub fn block(&mut self, name: &str) -> BlockId {
        self.func.new_block(name)
    }

    pub fn switch_to(&mut self, bb: BlockId) {
        self.cur = Some(bb);
    }

    pub fn current(&self) -> BlockId {
        self.cur.expect("no insertion block set")
    }

    fn push(&mut self, op: Op) -> Option<ValueId> {
        let bb = self.current();
        self.func.push_instr(bb, op)
    }

    fn pushv(&mut self, op: Op) -> ValueId {
        self.push(op).expect("op must produce a value")
    }

    // -- constants ----------------------------------------------------------
    pub fn const_i(&mut self, x: i64) -> ValueId {
        self.pushv(Op::ConstI(x))
    }

    pub fn const_f(&mut self, x: f64) -> ValueId {
        self.pushv(Op::ConstF(x))
    }

    pub fn const_b(&mut self, x: bool) -> ValueId {
        self.pushv(Op::ConstB(x))
    }

    // -- arithmetic ----------------------------------------------------------
    pub fn ibin(&mut self, op: BinOp, a: ValueId, b: ValueId) -> ValueId {
        self.pushv(Op::IBin(op, a, b))
    }

    pub fn fbin(&mut self, op: BinOp, a: ValueId, b: ValueId) -> ValueId {
        self.pushv(Op::FBin(op, a, b))
    }

    pub fn icmp(&mut self, op: CmpOp, a: ValueId, b: ValueId) -> ValueId {
        self.pushv(Op::ICmp(op, a, b))
    }

    pub fn fcmp(&mut self, op: CmpOp, a: ValueId, b: ValueId) -> ValueId {
        self.pushv(Op::FCmp(op, a, b))
    }

    pub fn not(&mut self, a: ValueId) -> ValueId {
        self.pushv(Op::Not(a))
    }

    pub fn select(&mut self, cond: ValueId, t: ValueId, f: ValueId) -> ValueId {
        let ty = self.func.value(t).ty;
        self.pushv(Op::Select { cond, t, f, ty })
    }

    pub fn itof(&mut self, a: ValueId) -> ValueId {
        self.pushv(Op::IToF(a))
    }

    pub fn ftoi(&mut self, a: ValueId) -> ValueId {
        self.pushv(Op::FToI(a))
    }

    // -- SSA -----------------------------------------------------------------
    /// Create an empty φ; fill incomings later with
    /// [`FunctionBuilder::set_phi_incomings`].
    pub fn phi(&mut self, ty: Type) -> ValueId {
        self.pushv(Op::Phi { ty, incomings: vec![] })
    }

    pub fn set_phi_incomings(&mut self, phi: ValueId, inc: Vec<(BlockId, ValueId)>) {
        let def = self.func.value(phi).def;
        let ValueDef::Instr(iid) = def else { panic!("phi value is not an instruction") };
        match &mut self.func.instr_mut(iid).op {
            Op::Phi { incomings, .. } => *incomings = inc,
            _ => panic!("set_phi_incomings on non-phi"),
        }
    }

    // -- memory ---------------------------------------------------------------
    pub fn load(&mut self, arr: ArrayId, idx: ValueId, elem: Type) -> ValueId {
        self.pushv(Op::Load { arr, idx, ty: elem })
    }

    pub fn store(&mut self, arr: ArrayId, idx: ValueId, val: ValueId) {
        self.push(Op::Store { arr, idx, val });
    }

    // -- DAE intrinsics ---------------------------------------------------------
    pub fn send_ld_addr(&mut self, chan: ChanId, mem: u32, idx: ValueId) {
        self.push(Op::SendLdAddr { chan, mem, idx });
    }

    pub fn send_st_addr(&mut self, chan: ChanId, mem: u32, idx: ValueId) {
        self.push(Op::SendStAddr { chan, mem, idx });
    }

    pub fn consume_val(&mut self, chan: ChanId, mem: u32, ty: Type) -> ValueId {
        self.pushv(Op::ConsumeVal { chan, mem, ty })
    }

    pub fn produce_val(&mut self, chan: ChanId, mem: u32, val: ValueId) {
        self.push(Op::ProduceVal { chan, mem, val });
    }

    pub fn poison_val(&mut self, chan: ChanId, mem: u32) {
        self.push(Op::PoisonVal { chan, mem, pred: None });
    }

    // -- terminators --------------------------------------------------------------
    pub fn br(&mut self, target: BlockId) {
        let bb = self.current();
        self.func.block_mut(bb).term = Terminator::Br(target);
    }

    pub fn cond_br(&mut self, cond: ValueId, t: BlockId, f: BlockId) {
        let bb = self.current();
        self.func.block_mut(bb).term = Terminator::CondBr { cond, t, f };
    }

    pub fn ret(&mut self) {
        let bb = self.current();
        self.func.block_mut(bb).term = Terminator::Ret;
    }

    /// Name the result value of the most recent instruction (printer sugar).
    pub fn name_value(&mut self, v: ValueId, name: &str) {
        self.func.values[v.index()].name = Some(name.to_string());
    }

    pub fn func(&self) -> &Function {
        &self.func
    }

    pub fn finish(self) -> Function {
        self.func
    }

    /// Direct access for tests that need to poke at internals.
    pub fn func_mut(&mut self) -> &mut Function {
        &mut self.func
    }

    /// The instruction id of the last pushed instruction in the current
    /// block.
    pub fn last_instr(&self) -> InstrId {
        *self
            .func
            .block(self.current())
            .instrs
            .last()
            .expect("current block has no instructions")
    }
}
