//! Instruction opcodes, terminators and channel kinds.

use super::{ArrayId, BlockId, ChanId, Type, ValueId};

/// Binary arithmetic / bitwise ops. Integer and float variants share
/// opcodes; the operand type disambiguates (verified by `verify`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Rem,
    And,
    Or,
    Xor,
    Shl,
    Shr,
    Min,
    Max,
}

/// Comparison predicates (signed for I64).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CmpOp {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

/// Direction/meaning of a DAE channel. One decoupled static load becomes a
/// `LdAddr` channel (AGU→DU) plus a `LdVal` channel (DU→CU) and, when the
/// AGU itself needs the value (LoD), a `LdValAgu` channel (DU→AGU). One
/// decoupled static store becomes a `StAddr` (AGU→DU) plus `StVal`
/// (CU→DU) pair; the store value carries the poison bit (§3.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ChanKind {
    LdAddr,
    StAddr,
    LdVal,
    LdValAgu,
    StVal,
}

/// Instruction opcodes.
#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    // -- constants ---------------------------------------------------------
    ConstI(i64),
    ConstF(f64),
    ConstB(bool),

    // -- arithmetic --------------------------------------------------------
    /// Integer binary op (operands + result I64).
    IBin(BinOp, ValueId, ValueId),
    /// Float binary op (operands + result F64).
    FBin(BinOp, ValueId, ValueId),
    /// Integer compare → B1.
    ICmp(CmpOp, ValueId, ValueId),
    /// Float compare → B1.
    FCmp(CmpOp, ValueId, ValueId),
    /// Boolean negate.
    Not(ValueId),
    /// `select cond, a, b` — result type `ty`.
    Select { cond: ValueId, t: ValueId, f: ValueId, ty: Type },
    /// Int → float.
    IToF(ValueId),
    /// Float → int (truncating).
    FToI(ValueId),

    // -- SSA ---------------------------------------------------------------
    /// φ node — result type `ty`, incoming `(pred block, value)` pairs.
    Phi { ty: Type, incomings: Vec<(BlockId, ValueId)> },

    // -- memory (pre-decoupling) --------------------------------------------
    /// `ty` is the element type of `arr` (denormalised here so
    /// `result_type` needs no module context).
    Load { arr: ArrayId, idx: ValueId, ty: Type },
    Store { arr: ArrayId, idx: ValueId, val: ValueId },

    // -- DAE channel intrinsics (§3.2) ---------------------------------------
    /// AGU: send a load request for `arr[idx]` on `chan`. `mem` tags the
    /// originating static memory op (bookkeeping/stats only; the FIFO
    /// stream is shared per array, which is exactly why the paper's
    /// ordering problem exists).
    SendLdAddr { chan: ChanId, mem: u32, idx: ValueId },
    /// AGU: send a store request for `arr[idx]` on `chan`.
    SendStAddr { chan: ChanId, mem: u32, idx: ValueId },
    /// CU / AGU: pop the next value from `chan` (a `LdVal`/`LdValAgu`
    /// channel). Result type = element type of the channel's array.
    ConsumeVal { chan: ChanId, mem: u32, ty: Type },
    /// CU: push the next store value on `chan`, poison bit clear.
    ProduceVal { chan: ChanId, mem: u32, val: ValueId },
    /// CU: push a poisoned store value on `chan` — the DU drops the
    /// matching store request without committing (§3.1). `pred` is an
    /// optional steering predicate (Algorithm 3 case 2): when present and
    /// false at runtime, the poison is a no-op (the paper's steering
    /// branches, expressed as predication — §9 notes the equivalence with
    /// GPU predication).
    PoisonVal { chan: ChanId, mem: u32, pred: Option<ValueId> },
}

impl Op {
    /// The result type, or `None` for void ops.
    pub fn result_type(&self) -> Option<Type> {
        match self {
            Op::ConstI(_) => Some(Type::I64),
            Op::ConstF(_) => Some(Type::F64),
            Op::ConstB(_) => Some(Type::B1),
            Op::IBin(..) => Some(Type::I64),
            Op::FBin(..) => Some(Type::F64),
            Op::ICmp(..) | Op::FCmp(..) | Op::Not(_) => Some(Type::B1),
            Op::Select { ty, .. } => Some(*ty),
            Op::IToF(_) => Some(Type::F64),
            Op::FToI(_) => Some(Type::I64),
            Op::Phi { ty, .. } => Some(*ty),
            Op::Load { ty, .. } => Some(*ty),
            Op::Store { .. } => None,
            Op::SendLdAddr { .. } | Op::SendStAddr { .. } => None,
            Op::ConsumeVal { ty, .. } => Some(*ty),
            Op::ProduceVal { .. } | Op::PoisonVal { .. } => None,
        }
    }

    /// Is this a memory request op as seen by the AGU (paper Alg. 1 hoists
    /// these)?
    pub fn is_send(&self) -> bool {
        matches!(self, Op::SendLdAddr { .. } | Op::SendStAddr { .. })
    }

    pub fn is_memory(&self) -> bool {
        matches!(self, Op::Load { .. } | Op::Store { .. })
    }

    /// Value operands read by this op (φ incomings included).
    pub fn uses(&self) -> Vec<ValueId> {
        match self {
            Op::ConstI(_) | Op::ConstF(_) | Op::ConstB(_) => vec![],
            Op::IBin(_, a, b) | Op::FBin(_, a, b) | Op::ICmp(_, a, b) | Op::FCmp(_, a, b) => {
                vec![*a, *b]
            }
            Op::Not(a) | Op::IToF(a) | Op::FToI(a) => vec![*a],
            Op::Select { cond, t, f, .. } => vec![*cond, *t, *f],
            Op::Phi { incomings, .. } => incomings.iter().map(|(_, v)| *v).collect(),
            Op::Load { idx, .. } => vec![*idx],
            Op::Store { idx, val, .. } => vec![*idx, *val],
            Op::SendLdAddr { idx, .. } | Op::SendStAddr { idx, .. } => vec![*idx],
            Op::ConsumeVal { .. } => vec![],
            Op::ProduceVal { val, .. } => vec![*val],
            Op::PoisonVal { pred, .. } => pred.iter().copied().collect(),
        }
    }

    /// Replace uses of `old` with `new`.
    pub fn replace_use(&mut self, old: ValueId, new: ValueId) {
        let r = |v: &mut ValueId| {
            if *v == old {
                *v = new;
            }
        };
        match self {
            Op::ConstI(_) | Op::ConstF(_) | Op::ConstB(_) => {}
            Op::IBin(_, a, b) | Op::FBin(_, a, b) | Op::ICmp(_, a, b) | Op::FCmp(_, a, b) => {
                r(a);
                r(b);
            }
            Op::Not(a) | Op::IToF(a) | Op::FToI(a) => r(a),
            Op::Select { cond, t, f, .. } => {
                r(cond);
                r(t);
                r(f);
            }
            Op::Phi { incomings, .. } => {
                for (_, v) in incomings.iter_mut() {
                    r(v);
                }
            }
            Op::Load { idx, .. } => r(idx),
            Op::Store { idx, val, .. } => {
                r(idx);
                r(val);
            }
            Op::SendLdAddr { idx, .. } | Op::SendStAddr { idx, .. } => r(idx),
            Op::ConsumeVal { .. } => {}
            Op::ProduceVal { val, .. } => r(val),
            Op::PoisonVal { .. } => {}
        }
    }

    /// Does the op have side effects (must not be removed by DCE)?
    pub fn has_side_effect(&self) -> bool {
        matches!(
            self,
            Op::Store { .. }
                | Op::SendLdAddr { .. }
                | Op::SendStAddr { .. }
                | Op::ConsumeVal { .. }
                | Op::ProduceVal { .. }
                | Op::PoisonVal { .. }
        )
    }
}

/// Block terminators.
#[derive(Clone, Debug, PartialEq)]
pub enum Terminator {
    /// Freshly created block; the verifier rejects this.
    Unterminated,
    Br(BlockId),
    CondBr { cond: ValueId, t: BlockId, f: BlockId },
    Ret,
}

impl Terminator {
    pub fn succs(&self) -> Vec<BlockId> {
        match self {
            Terminator::Unterminated | Terminator::Ret => vec![],
            Terminator::Br(b) => vec![*b],
            Terminator::CondBr { t, f, .. } => {
                if t == f {
                    vec![*t]
                } else {
                    vec![*t, *f]
                }
            }
        }
    }

    /// Retarget the `old` successor to `new`.
    pub fn replace_succ(&mut self, old: BlockId, new: BlockId) {
        match self {
            Terminator::Br(b) => {
                if *b == old {
                    *b = new;
                }
            }
            Terminator::CondBr { t, f, .. } => {
                if *t == old {
                    *t = new;
                }
                if *f == old {
                    *f = new;
                }
            }
            _ => {}
        }
    }
}
