//! Textual IR emission. The format round-trips through [`super::parser`]
//! and is used for golden tests and debugging dumps.
//!
//! ```text
//! array @A : f64[100]
//! chan ch0 : st_addr @A mem3
//!
//! func @hist(%n: i64) {
//! entry:
//!   %c0 = const.i 0
//!   br header
//! header:
//!   %i = phi i64 [entry: %c0], [body: %inext]
//!   ...
//! }
//! ```

use super::ops::{BinOp, ChanKind, CmpOp, Op, Terminator};
use super::{Function, Module, ValueId};
use std::fmt::Write;

pub fn binop_str(op: BinOp) -> &'static str {
    match op {
        BinOp::Add => "add",
        BinOp::Sub => "sub",
        BinOp::Mul => "mul",
        BinOp::Div => "div",
        BinOp::Rem => "rem",
        BinOp::And => "and",
        BinOp::Or => "or",
        BinOp::Xor => "xor",
        BinOp::Shl => "shl",
        BinOp::Shr => "shr",
        BinOp::Min => "min",
        BinOp::Max => "max",
    }
}

pub fn cmpop_str(op: CmpOp) -> &'static str {
    match op {
        CmpOp::Eq => "eq",
        CmpOp::Ne => "ne",
        CmpOp::Lt => "lt",
        CmpOp::Le => "le",
        CmpOp::Gt => "gt",
        CmpOp::Ge => "ge",
    }
}

pub fn chankind_str(k: ChanKind) -> &'static str {
    match k {
        ChanKind::LdAddr => "ld_addr",
        ChanKind::StAddr => "st_addr",
        ChanKind::LdVal => "ld_val",
        ChanKind::LdValAgu => "ld_val_agu",
        ChanKind::StVal => "st_val",
    }
}

/// Printable name for a value: `%name` if it has one, else `%vN`.
fn vname(f: &Function, v: ValueId) -> String {
    match &f.value(v).name {
        Some(n) => format!("%{n}"),
        None => format!("%v{}", v.0),
    }
}

pub fn print_function(m: &Module, f: &Function) -> String {
    let mut s = String::new();
    let params: Vec<String> = f
        .params
        .iter()
        .map(|&p| format!("{}: {}", vname(f, p), f.value(p).ty))
        .collect();
    let _ = writeln!(s, "func @{}({}) {{", f.name, params.join(", "));
    for (bi, b) in f.blocks.iter().enumerate() {
        let _ = writeln!(s, "{}:", b.name);
        for &iid in &b.instrs {
            let instr = f.instr(iid);
            let lhs = instr.result.map(|r| format!("{} = ", vname(f, r))).unwrap_or_default();
            let rhs = print_op(m, f, &instr.op);
            let _ = writeln!(s, "  {lhs}{rhs}");
        }
        let term = match &b.term {
            Terminator::Unterminated => "<unterminated>".to_string(),
            Terminator::Br(t) => format!("br {}", f.block(*t).name),
            Terminator::CondBr { cond, t, f: fb } => format!(
                "condbr {}, {}, {}",
                vname(f, *cond),
                f.block(*t).name,
                f.block(*fb).name
            ),
            Terminator::Ret => "ret".to_string(),
        };
        let _ = writeln!(s, "  {term}");
        if bi + 1 != f.blocks.len() {
            // nothing between blocks
        }
    }
    let _ = writeln!(s, "}}");
    s
}

pub fn print_op(m: &Module, f: &Function, op: &Op) -> String {
    match op {
        Op::ConstI(x) => format!("const.i {x}"),
        Op::ConstF(x) => format!("const.f {x:?}"),
        Op::ConstB(x) => format!("const.b {x}"),
        Op::IBin(o, a, b) => format!("{}.i {}, {}", binop_str(*o), vname(f, *a), vname(f, *b)),
        Op::FBin(o, a, b) => format!("{}.f {}, {}", binop_str(*o), vname(f, *a), vname(f, *b)),
        Op::ICmp(o, a, b) => format!("icmp.{} {}, {}", cmpop_str(*o), vname(f, *a), vname(f, *b)),
        Op::FCmp(o, a, b) => format!("fcmp.{} {}, {}", cmpop_str(*o), vname(f, *a), vname(f, *b)),
        Op::Not(a) => format!("not {}", vname(f, *a)),
        Op::Select { cond, t, f: fv, .. } => {
            format!("select {}, {}, {}", vname(f, *cond), vname(f, *t), vname(f, *fv))
        }
        Op::IToF(a) => format!("itof {}", vname(f, *a)),
        Op::FToI(a) => format!("ftoi {}", vname(f, *a)),
        Op::Phi { ty, incomings } => {
            let inc: Vec<String> = incomings
                .iter()
                .map(|(bb, v)| format!("[{}: {}]", f.block(*bb).name, vname(f, *v)))
                .collect();
            format!("phi {ty} {}", inc.join(", "))
        }
        Op::Load { arr, idx, .. } => {
            format!("load @{}[{}]", m.array(*arr).name, vname(f, *idx))
        }
        Op::Store { arr, idx, val } => format!(
            "store @{}[{}], {}",
            m.array(*arr).name,
            vname(f, *idx),
            vname(f, *val)
        ),
        Op::SendLdAddr { chan, mem, idx } => {
            format!("send_ld_addr {chan}:m{mem}, {}", vname(f, *idx))
        }
        Op::SendStAddr { chan, mem, idx } => {
            format!("send_st_addr {chan}:m{mem}, {}", vname(f, *idx))
        }
        Op::ConsumeVal { chan, mem, .. } => format!("consume_val {chan}:m{mem}"),
        Op::ProduceVal { chan, mem, val } => {
            format!("produce_val {chan}:m{mem}, {}", vname(f, *val))
        }
        Op::PoisonVal { chan, mem, pred } => match pred {
            Some(p) => format!("poison_val {chan}:m{mem} if {}", vname(f, *p)),
            None => format!("poison_val {chan}:m{mem}"),
        },
    }
}

pub fn print_module(m: &Module) -> String {
    let mut s = String::new();
    for a in &m.arrays {
        let _ = writeln!(s, "array @{} : {}[{}]", a.name, a.elem, a.size);
    }
    for (i, c) in m.chans.iter().enumerate() {
        let _ = writeln!(s, "chan ch{} : {} @{}", i, chankind_str(c.kind), m.array(c.arr).name);
    }
    if !m.arrays.is_empty() || !m.chans.is_empty() {
        let _ = writeln!(s);
    }
    for f in &m.funcs {
        s.push_str(&print_function(m, f));
        let _ = writeln!(s);
    }
    s
}
