//! Recursive-descent parser for the textual IR format emitted by
//! [`super::printer`]. Used by golden tests and by workloads that prefer
//! source-level definitions over builder calls.

use super::ops::{BinOp, ChanKind, CmpOp, Op, Terminator};
use super::types::Type;
use super::{ArrayId, BlockId, ChanId, Function, Module, ValueId};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;

pub fn parse_module(src: &str) -> Result<Module> {
    Parser::new(src).module()
}

/// Parse a module containing exactly one function; convenience for tests.
pub fn parse_single(src: &str) -> Result<(Module, Function)> {
    let mut m = parse_module(src)?;
    if m.funcs.len() != 1 {
        bail!("expected exactly one function, got {}", m.funcs.len());
    }
    let f = m.funcs.pop().unwrap();
    Ok((m, f))
}

struct Parser<'a> {
    lines: Vec<&'a str>,
    pos: usize,
}

/// Pending φ operands: value names are resolved after the whole body is
/// parsed (forward references).
struct PendingPhi {
    instr_idx: usize, // into func.instrs
    incomings: Vec<(String, String)>, // (block name, value name)
}

impl<'a> Parser<'a> {
    fn new(src: &'a str) -> Self {
        let lines = src
            .lines()
            .map(|l| {
                // strip comments
                match l.find("//") {
                    Some(i) => &l[..i],
                    None => l,
                }
            })
            .map(str::trim)
            .collect();
        Parser { lines, pos: 0 }
    }

    fn peek(&self) -> Option<&'a str> {
        self.lines[self.pos..].iter().copied().find(|l| !l.is_empty())
    }

    fn next_line(&mut self) -> Option<&'a str> {
        while self.pos < self.lines.len() {
            let l = self.lines[self.pos];
            self.pos += 1;
            if !l.is_empty() {
                return Some(l);
            }
        }
        None
    }

    fn module(&mut self) -> Result<Module> {
        let mut m = Module::new();
        let mut arrays: HashMap<String, ArrayId> = HashMap::new();
        while let Some(l) = self.peek() {
            if l.starts_with("array") {
                let l = self.next_line().unwrap();
                // array @A : f64[100]
                let rest = l.strip_prefix("array").unwrap().trim();
                let (name, rest) = rest
                    .split_once(':')
                    .ok_or_else(|| anyhow!("bad array decl: {l}"))?;
                let name = name.trim().trim_start_matches('@').to_string();
                let rest = rest.trim();
                let (ty_s, size_s) = rest
                    .split_once('[')
                    .ok_or_else(|| anyhow!("bad array decl: {l}"))?;
                let ty = parse_type(ty_s.trim())?;
                let size: usize = size_s
                    .trim_end_matches(']')
                    .trim()
                    .parse()
                    .with_context(|| format!("bad array size in: {l}"))?;
                let id = m.add_array(&name, ty, size);
                arrays.insert(name, id);
            } else if l.starts_with("chan") {
                let l = self.next_line().unwrap();
                // chan ch0 : st_addr @A mem3
                let rest = l.strip_prefix("chan").unwrap().trim();
                let (_name, rest) =
                    rest.split_once(':').ok_or_else(|| anyhow!("bad chan decl: {l}"))?;
                let toks: Vec<&str> = rest.split_whitespace().collect();
                if toks.len() != 2 {
                    bail!("bad chan decl: {l}");
                }
                let kind = parse_chankind(toks[0])?;
                let arr = *arrays
                    .get(toks[1].trim_start_matches('@'))
                    .ok_or_else(|| anyhow!("unknown array in chan decl: {l}"))?;
                m.add_chan(kind, arr);
            } else if l.starts_with("func") {
                let f = self.function(&m, &arrays)?;
                m.funcs.push(f);
            } else {
                bail!("unexpected line: {l}");
            }
        }
        Ok(m)
    }

    fn function(&mut self, m: &Module, arrays: &HashMap<String, ArrayId>) -> Result<Function> {
        let header = self.next_line().unwrap();
        // func @name(%a: i64, %b: f64) {
        let rest = header.strip_prefix("func").unwrap().trim();
        let open = rest.find('(').ok_or_else(|| anyhow!("bad func header: {header}"))?;
        let name = rest[..open].trim().trim_start_matches('@').to_string();
        let close = rest.rfind(')').ok_or_else(|| anyhow!("bad func header: {header}"))?;
        let params_s = &rest[open + 1..close];
        let mut f = Function::new(&name);
        let mut values: HashMap<String, ValueId> = HashMap::new();
        for p in params_s.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (pn, pt) = p.split_once(':').ok_or_else(|| anyhow!("bad param: {p}"))?;
            let pn = pn.trim().trim_start_matches('%');
            let v = f.add_param(pn, parse_type(pt.trim())?);
            values.insert(pn.to_string(), v);
        }

        // First pass over the body: collect block names so branches can
        // forward-reference.
        let body_start = self.pos;
        let mut blocks: HashMap<String, BlockId> = HashMap::new();
        let mut depth = 0usize;
        for i in self.pos..self.lines.len() {
            let l = self.lines[i];
            if l.is_empty() {
                continue;
            }
            if l.ends_with('{') {
                depth += 1;
            }
            if l == "}" {
                if depth == 0 {
                    break;
                }
                depth -= 1;
                continue;
            }
            if l.ends_with(':') && !l.contains(' ') {
                let bn = l.trim_end_matches(':').to_string();
                let id = f.new_block(&bn);
                blocks.insert(bn, id);
            }
        }
        self.pos = body_start;

        let mut cur: Option<BlockId> = None;
        let mut pending_phis: Vec<PendingPhi> = Vec::new();
        loop {
            let l = self
                .next_line()
                .ok_or_else(|| anyhow!("unexpected EOF in function @{name}"))?;
            if l == "}" {
                break;
            }
            if l.ends_with(':') && !l.contains(' ') {
                cur = Some(blocks[l.trim_end_matches(':')]);
                continue;
            }
            let bb = cur.ok_or_else(|| anyhow!("instruction before first block: {l}"))?;
            self.instr_line(l, m, arrays, &blocks, &mut values, &mut pending_phis, &mut f, bb)?;
        }

        // Resolve φ operands now that every value name is known.
        for p in pending_phis {
            let mut inc = Vec::with_capacity(p.incomings.len());
            for (bn, vn) in p.incomings {
                let bb = *blocks
                    .get(&bn)
                    .ok_or_else(|| anyhow!("phi references unknown block {bn}"))?;
                let v = *values
                    .get(&vn)
                    .ok_or_else(|| anyhow!("phi references unknown value %{vn}"))?;
                inc.push((bb, v));
            }
            match &mut f.instrs[p.instr_idx].op {
                Op::Phi { incomings, .. } => *incomings = inc,
                _ => unreachable!(),
            }
        }
        Ok(f)
    }

    #[allow(clippy::too_many_arguments)]
    fn instr_line(
        &mut self,
        l: &str,
        m: &Module,
        arrays: &HashMap<String, ArrayId>,
        blocks: &HashMap<String, BlockId>,
        values: &mut HashMap<String, ValueId>,
        pending_phis: &mut Vec<PendingPhi>,
        f: &mut Function,
        bb: BlockId,
    ) -> Result<()> {
        // terminators
        if let Some(t) = l.strip_prefix("br ") {
            let target = *blocks
                .get(t.trim())
                .ok_or_else(|| anyhow!("unknown block: {t}"))?;
            f.block_mut(bb).term = Terminator::Br(target);
            return Ok(());
        }
        if let Some(t) = l.strip_prefix("condbr ") {
            let parts: Vec<&str> = t.split(',').map(str::trim).collect();
            if parts.len() != 3 {
                bail!("bad condbr: {l}");
            }
            let cond = lookup(values, parts[0])?;
            let tb = *blocks.get(parts[1]).ok_or_else(|| anyhow!("unknown block {}", parts[1]))?;
            let fb = *blocks.get(parts[2]).ok_or_else(|| anyhow!("unknown block {}", parts[2]))?;
            f.block_mut(bb).term = Terminator::CondBr { cond, t: tb, f: fb };
            return Ok(());
        }
        if l == "ret" {
            f.block_mut(bb).term = Terminator::Ret;
            return Ok(());
        }

        // `%res = op ...` or bare side-effect op
        let (res_name, rhs) = match l.split_once('=') {
            Some((lhs, rhs)) if lhs.trim_start().starts_with('%') => {
                (Some(lhs.trim().trim_start_matches('%').to_string()), rhs.trim())
            }
            _ => (None, l),
        };

        let (opname, rest) = match rhs.split_once(char::is_whitespace) {
            Some((a, b)) => (a, b.trim()),
            None => (rhs, ""),
        };

        let op: Op = if opname == "phi" {
            // phi i64 [bb: %v], [bb2: %w]
            let (ty_s, inc_s) = rest
                .split_once(char::is_whitespace)
                .ok_or_else(|| anyhow!("bad phi: {l}"))?;
            let ty = parse_type(ty_s)?;
            let mut incomings = Vec::new();
            for part in split_brackets(inc_s) {
                let inner = part.trim().trim_start_matches('[').trim_end_matches(']');
                let (bn, vn) = inner.split_once(':').ok_or_else(|| anyhow!("bad phi arm: {part}"))?;
                incomings.push((bn.trim().to_string(), vn.trim().trim_start_matches('%').to_string()));
            }
            let iid = f.create_instr(Op::Phi { ty, incomings: vec![] });
            f.blocks[bb.index()].instrs.push(iid);
            pending_phis.push(PendingPhi { instr_idx: iid.index(), incomings });
            if let Some(r) = f.instrs[iid.index()].result {
                if let Some(n) = res_name {
                    f.values[r.index()].name = Some(n.clone());
                    values.insert(n, r);
                }
            }
            return Ok(());
        } else if opname == "const.i" {
            Op::ConstI(rest.parse()?)
        } else if opname == "const.f" {
            Op::ConstF(rest.parse()?)
        } else if opname == "const.b" {
            Op::ConstB(rest.parse()?)
        } else if let Some(o) = opname.strip_suffix(".i").and_then(parse_binop) {
            let (a, b) = two_operands(values, rest)?;
            Op::IBin(o, a, b)
        } else if let Some(o) = opname.strip_suffix(".f").and_then(parse_binop) {
            let (a, b) = two_operands(values, rest)?;
            Op::FBin(o, a, b)
        } else if let Some(c) = opname.strip_prefix("icmp.") {
            let (a, b) = two_operands(values, rest)?;
            Op::ICmp(parse_cmpop(c)?, a, b)
        } else if let Some(c) = opname.strip_prefix("fcmp.") {
            let (a, b) = two_operands(values, rest)?;
            Op::FCmp(parse_cmpop(c)?, a, b)
        } else if opname == "not" {
            Op::Not(lookup(values, rest)?)
        } else if opname == "itof" {
            Op::IToF(lookup(values, rest)?)
        } else if opname == "ftoi" {
            Op::FToI(lookup(values, rest)?)
        } else if opname == "select" {
            let parts: Vec<&str> = rest.split(',').map(str::trim).collect();
            if parts.len() != 3 {
                bail!("bad select: {l}");
            }
            let cond = lookup(values, parts[0])?;
            let t = lookup(values, parts[1])?;
            let fv = lookup(values, parts[2])?;
            let ty = f.value(t).ty;
            Op::Select { cond, t, f: fv, ty }
        } else if opname == "load" {
            // load @A[%i]
            let (arr, idx) = parse_mem_ref(values, arrays, rest)?;
            let ty = m.array(arr).elem;
            Op::Load { arr, idx, ty }
        } else if opname == "store" {
            // store @A[%i], %v
            let (mem, val_s) = rest
                .rsplit_once(',')
                .ok_or_else(|| anyhow!("bad store: {l}"))?;
            let (arr, idx) = parse_mem_ref(values, arrays, mem.trim())?;
            let val = lookup(values, val_s.trim())?;
            Op::Store { arr, idx, val }
        } else if opname == "send_ld_addr" || opname == "send_st_addr" {
            let (c, i) = rest.split_once(',').ok_or_else(|| anyhow!("bad send: {l}"))?;
            let (chan, mem) = parse_chan_mem(c.trim())?;
            let idx = lookup(values, i.trim())?;
            if opname == "send_ld_addr" {
                Op::SendLdAddr { chan, mem, idx }
            } else {
                Op::SendStAddr { chan, mem, idx }
            }
        } else if opname == "consume_val" {
            let (chan, mem) = parse_chan_mem(rest.trim())?;
            let ty = m.array(m.chan(chan).arr).elem;
            Op::ConsumeVal { chan, mem, ty }
        } else if opname == "produce_val" {
            let (c, v) = rest.split_once(',').ok_or_else(|| anyhow!("bad produce: {l}"))?;
            let (chan, mem) = parse_chan_mem(c.trim())?;
            Op::ProduceVal { chan, mem, val: lookup(values, v.trim())? }
        } else if opname == "poison_val" {
            // `poison_val ch0:m1` or `poison_val ch0:m1 if %flag`
            let (cm, pred) = match rest.split_once(" if ") {
                Some((cm, p)) => (cm.trim(), Some(lookup(values, p.trim())?)),
                None => (rest.trim(), None),
            };
            let (chan, mem) = parse_chan_mem(cm)?;
            Op::PoisonVal { chan, mem, pred }
        } else {
            bail!("unknown op: {l}");
        };

        let iid = f.create_instr(op);
        f.blocks[bb.index()].instrs.push(iid);
        if let Some(r) = f.instrs[iid.index()].result {
            if let Some(n) = res_name {
                f.values[r.index()].name = Some(n.clone());
                values.insert(n, r);
            }
        }
        Ok(())
    }
}

fn lookup(values: &HashMap<String, ValueId>, s: &str) -> Result<ValueId> {
    let name = s.trim().trim_start_matches('%');
    values
        .get(name)
        .copied()
        .ok_or_else(|| anyhow!("unknown value %{name}"))
}

fn two_operands(values: &HashMap<String, ValueId>, rest: &str) -> Result<(ValueId, ValueId)> {
    let (a, b) = rest
        .split_once(',')
        .ok_or_else(|| anyhow!("expected two operands: {rest}"))?;
    Ok((lookup(values, a)?, lookup(values, b)?))
}

fn parse_mem_ref(
    values: &HashMap<String, ValueId>,
    arrays: &HashMap<String, ArrayId>,
    s: &str,
) -> Result<(ArrayId, ValueId)> {
    // @A[%i]
    let s = s.trim().trim_start_matches('@');
    let open = s.find('[').ok_or_else(|| anyhow!("bad memory ref: {s}"))?;
    let arr = *arrays
        .get(&s[..open])
        .ok_or_else(|| anyhow!("unknown array @{}", &s[..open]))?;
    let idx = lookup(values, s[open + 1..].trim_end_matches(']'))?;
    Ok((arr, idx))
}

/// Parse `ch0:m3` (channel + static-mem-op tag). A bare `ch0` gets tag 0.
fn parse_chan_mem(s: &str) -> Result<(ChanId, u32)> {
    let (c, m) = match s.split_once(':') {
        Some((c, m)) => (c, m.strip_prefix('m').ok_or_else(|| anyhow!("bad mem tag: {s}"))?),
        None => (s, "0"),
    };
    let chan = ChanId(
        c.strip_prefix("ch")
            .ok_or_else(|| anyhow!("bad channel: {s}"))?
            .parse()?,
    );
    Ok((chan, m.parse()?))
}

fn parse_type(s: &str) -> Result<Type> {
    match s {
        "i64" => Ok(Type::I64),
        "f64" => Ok(Type::F64),
        "b1" => Ok(Type::B1),
        _ => bail!("unknown type: {s}"),
    }
}

fn parse_binop(s: &str) -> Option<BinOp> {
    Some(match s {
        "add" => BinOp::Add,
        "sub" => BinOp::Sub,
        "mul" => BinOp::Mul,
        "div" => BinOp::Div,
        "rem" => BinOp::Rem,
        "and" => BinOp::And,
        "or" => BinOp::Or,
        "xor" => BinOp::Xor,
        "shl" => BinOp::Shl,
        "shr" => BinOp::Shr,
        "min" => BinOp::Min,
        "max" => BinOp::Max,
        _ => return None,
    })
}

fn parse_cmpop(s: &str) -> Result<CmpOp> {
    Ok(match s {
        "eq" => CmpOp::Eq,
        "ne" => CmpOp::Ne,
        "lt" => CmpOp::Lt,
        "le" => CmpOp::Le,
        "gt" => CmpOp::Gt,
        "ge" => CmpOp::Ge,
        _ => bail!("unknown cmp op: {s}"),
    })
}

fn parse_chankind(s: &str) -> Result<ChanKind> {
    Ok(match s {
        "ld_addr" => ChanKind::LdAddr,
        "st_addr" => ChanKind::StAddr,
        "ld_val" => ChanKind::LdVal,
        "ld_val_agu" => ChanKind::LdValAgu,
        "st_val" => ChanKind::StVal,
        _ => bail!("unknown chan kind: {s}"),
    })
}

/// Split `"[a: b], [c: d]"` into bracketed chunks.
fn split_brackets(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '[' => {
                if depth == 0 {
                    start = i;
                }
                depth += 1;
            }
            ']' => {
                depth -= 1;
                if depth == 0 {
                    out.push(&s[start..=i]);
                }
            }
            _ => {}
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::printer::print_module;

    const HIST: &str = r#"
array @A : i64[100]
array @idx : i64[100]

func @hist(%n: i64) {
entry:
  %c0 = const.i 0
  br header
header:
  %i = phi i64 [entry: %c0], [body_end: %inext]
  %cc = icmp.lt %i, %n
  condbr %cc, body, exit
body:
  %w = load @idx[%i]
  %a = load @A[%w]
  %czero = const.i 0
  %p = icmp.gt %a, %czero
  condbr %p, then, body_end
then:
  %c1 = const.i 1
  %a2 = add.i %a, %c1
  store @A[%w], %a2
  br body_end
body_end:
  %c1b = const.i 1
  %inext = add.i %i, %c1b
  br header
exit:
  ret
}
"#;

    #[test]
    fn parse_hist() {
        let (m, f) = parse_single(HIST).unwrap();
        assert_eq!(m.arrays.len(), 2);
        assert_eq!(f.blocks.len(), 6);
        assert_eq!(f.params.len(), 1);
        // the φ has two incomings
        let phis: Vec<_> = f
            .instrs
            .iter()
            .filter(|i| matches!(i.op, Op::Phi { .. }))
            .collect();
        assert_eq!(phis.len(), 1);
    }

    #[test]
    fn roundtrip_hist() {
        let mut m = parse_module(HIST).unwrap();
        let printed = print_module(&m);
        let m2 = parse_module(&printed).unwrap();
        let printed2 = print_module(&m2);
        assert_eq!(printed, printed2, "print->parse->print must be stable");
        // keep m alive for borrowck clarity
        m.funcs.clear();
    }

    #[test]
    fn parse_dae_intrinsics() {
        let src = r#"
array @A : i64[8]
chan ch0 : st_addr @A
chan ch1 : st_val @A

func @agu(%n: i64) {
entry:
  %c0 = const.i 0
  send_st_addr ch0:m0, %c0
  ret
}

func @cu(%n: i64) {
entry:
  %c7 = const.i 7
  produce_val ch1:m0, %c7
  poison_val ch1:m0
  ret
}
"#;
        let m = parse_module(src).unwrap();
        assert_eq!(m.funcs.len(), 2);
        assert_eq!(m.chans.len(), 2);
        let printed = print_module(&m);
        let m2 = parse_module(&printed).unwrap();
        assert_eq!(print_module(&m2), printed);
    }

    #[test]
    fn errors_on_unknown_value() {
        let src = r#"
func @f() {
entry:
  %x = add.i %nope, %nope
  ret
}
"#;
        assert!(parse_module(src).is_err());
    }
}
