//! IR verifier: structural and SSA well-formedness checks. Run after every
//! transform in debug builds and in tests.

use super::ops::{Op, Terminator};
use super::{Function, Module, ValueDef};
use crate::analysis::domtree::DomTree;
use anyhow::{bail, Result};

/// Verify a function. Checks:
/// 1. every reachable block is terminated;
/// 2. φs appear only at the top of a block and list each predecessor
///    exactly once;
/// 3. every use is dominated by its definition (standard SSA rule; φ uses
///    are checked at the end of the corresponding incoming block);
/// 4. operand types match op expectations;
/// 5. the CFG is reducible: every retreating edge is a true backedge,
///    i.e. targets a loop header that dominates its latch.
pub fn verify_function(m: &Module, f: &Function) -> Result<()> {
    let n = f.num_blocks();
    if n == 0 {
        bail!("function @{} has no blocks", f.name);
    }

    let preds = f.preds();
    let dom = DomTree::new(f);

    // Reducibility (iterative DFS colouring). The loop analysis and the
    // lint path summaries both assume a natural-loop decomposition
    // exists; an edge retreating into a cycle without passing its header
    // has no such reading, so name it precisely.
    {
        const WHITE: u8 = 0;
        const GREY: u8 = 1;
        const BLACK: u8 = 2;
        let mut color = vec![WHITE; n];
        let mut stack: Vec<(super::BlockId, usize)> = vec![(f.entry, 0)];
        color[f.entry.index()] = GREY;
        while let Some(frame) = stack.last_mut() {
            let b = frame.0;
            let succs = f.succs(b);
            if frame.1 < succs.len() {
                let s = succs[frame.1];
                frame.1 += 1;
                match color[s.index()] {
                    WHITE => {
                        color[s.index()] = GREY;
                        stack.push((s, 0));
                    }
                    GREY => {
                        if !dom.dominates(s, b) {
                            bail!(
                                "irreducible control flow in @{}: retreating edge \
                                 {} -> {} re-enters a loop whose header {} does not \
                                 dominate the edge's source",
                                f.name,
                                f.block(b).name,
                                f.block(s).name,
                                f.block(s).name
                            );
                        }
                    }
                    _ => {}
                }
            } else {
                color[b.index()] = BLACK;
                stack.pop();
            }
        }
    }

    for (bi, b) in f.blocks.iter().enumerate() {
        if !dom.is_reachable(super::BlockId(bi as u32)) {
            continue;
        }
        if matches!(b.term, Terminator::Unterminated) {
            bail!("block {} in @{} is unterminated", b.name, f.name);
        }
        // φ placement + pred coverage
        let mut seen_nonphi = false;
        for &iid in &b.instrs {
            let instr = f.instr(iid);
            match &instr.op {
                Op::Phi { incomings, .. } => {
                    if seen_nonphi {
                        bail!("φ after non-φ in block {} of @{}", b.name, f.name);
                    }
                    let mut ps: Vec<_> = preds[bi]
                        .iter()
                        .filter(|p| dom.is_reachable(**p))
                        .copied()
                        .collect();
                    ps.sort();
                    ps.dedup();
                    let mut inc: Vec<_> = incomings
                        .iter()
                        .map(|(bb, _)| *bb)
                        .filter(|p| dom.is_reachable(*p))
                        .collect();
                    inc.sort();
                    inc.dedup();
                    if ps != inc {
                        bail!(
                            "φ in block {} of @{} incoming blocks {:?} != reachable preds {:?}",
                            b.name,
                            f.name,
                            inc,
                            ps
                        );
                    }
                }
                _ => seen_nonphi = true,
            }
        }
    }

    // Dominance of uses.
    let instr_block = instr_block_map(f);
    for (bi, b) in f.blocks.iter().enumerate() {
        let bb = super::BlockId(bi as u32);
        if !dom.is_reachable(bb) {
            continue;
        }
        let check_use = |user_desc: &str, v: super::ValueId, at_block: super::BlockId, pos: Option<usize>| -> Result<()> {
            match f.value(v).def {
                ValueDef::Param(_) => Ok(()),
                ValueDef::Instr(def_iid) => {
                    let Some(&def_bb) = instr_block.get(&def_iid) else {
                        bail!(
                            "use of detached instruction result {v} by {user_desc} in @{}",
                            f.name
                        );
                    };
                    if def_bb == at_block {
                        // must come earlier in the same block (when pos known)
                        if let Some(use_pos) = pos {
                            let def_pos = f
                                .block(def_bb)
                                .instrs
                                .iter()
                                .position(|&i| i == def_iid)
                                .unwrap();
                            if def_pos >= use_pos {
                                bail!(
                                    "{user_desc} in @{} uses {v} before its definition in block {}",
                                    f.name,
                                    f.block(def_bb).name
                                );
                            }
                        }
                        Ok(())
                    } else if dom.dominates(def_bb, at_block) {
                        Ok(())
                    } else {
                        bail!(
                            "{user_desc} in block {} of @{} uses {v} whose def block {} does not dominate",
                            f.block(at_block).name,
                            f.name,
                            f.block(def_bb).name
                        )
                    }
                }
            }
        };

        for (pos, &iid) in b.instrs.iter().enumerate() {
            let instr = f.instr(iid);
            match &instr.op {
                Op::Phi { incomings, .. } => {
                    for (in_bb, v) in incomings {
                        if dom.is_reachable(*in_bb) {
                            check_use("φ incoming", *v, *in_bb, None)?;
                        }
                    }
                }
                op => {
                    for v in op.uses() {
                        check_use("instruction", v, bb, Some(pos))?;
                    }
                }
            }
        }
        if let Terminator::CondBr { cond, .. } = b.term {
            check_use("condbr", cond, bb, None)?;
            let ty = f.value(cond).ty;
            if ty != super::Type::B1 {
                bail!("condbr condition has type {ty}, want b1, in @{}", f.name);
            }
        }
    }

    // Type checks.
    for instr in &f.instrs {
        type_check(m, f, &instr.op)?;
    }

    Ok(())
}

pub fn verify_module(m: &Module) -> Result<()> {
    for f in &m.funcs {
        verify_function(m, f)?;
    }
    Ok(())
}

fn instr_block_map(
    f: &Function,
) -> std::collections::HashMap<super::InstrId, super::BlockId> {
    let mut map = std::collections::HashMap::new();
    for (bi, b) in f.blocks.iter().enumerate() {
        for &iid in &b.instrs {
            map.insert(iid, super::BlockId(bi as u32));
        }
    }
    map
}

fn type_check(m: &Module, f: &Function, op: &Op) -> Result<()> {
    use super::Type::*;
    let ty = |v: super::ValueId| f.value(v).ty;
    match op {
        Op::IBin(_, a, b) => {
            if ty(*a) != I64 || ty(*b) != I64 {
                bail!("ibin operands must be i64 in @{}", f.name);
            }
        }
        Op::FBin(_, a, b) => {
            if ty(*a) != F64 || ty(*b) != F64 {
                bail!("fbin operands must be f64 in @{}", f.name);
            }
        }
        Op::ICmp(_, a, b) => {
            if ty(*a) != I64 || ty(*b) != I64 {
                bail!("icmp operands must be i64 in @{}", f.name);
            }
        }
        Op::FCmp(_, a, b) => {
            if ty(*a) != F64 || ty(*b) != F64 {
                bail!("fcmp operands must be f64 in @{}", f.name);
            }
        }
        Op::Not(a) => {
            if ty(*a) != B1 {
                bail!("not operand must be b1 in @{}", f.name);
            }
        }
        Op::Select { cond, t, f: fv, ty: want } => {
            if ty(*cond) != B1 {
                bail!("select condition must be b1 in @{}", f.name);
            }
            if ty(*t) != *want || ty(*fv) != *want {
                bail!("select arm types disagree in @{}", f.name);
            }
        }
        Op::Load { arr, idx, ty: want } => {
            if ty(*idx) != I64 {
                bail!("load index must be i64 in @{}", f.name);
            }
            if m.array(*arr).elem != *want {
                bail!("load type mismatch for @{} in @{}", m.array(*arr).name, f.name);
            }
        }
        Op::Store { arr, idx, val } => {
            if ty(*idx) != I64 {
                bail!("store index must be i64 in @{}", f.name);
            }
            if ty(*val) != m.array(*arr).elem {
                bail!("store value type mismatch for @{} in @{}", m.array(*arr).name, f.name);
            }
        }
        Op::SendLdAddr { idx, .. } | Op::SendStAddr { idx, .. } => {
            if ty(*idx) != I64 {
                bail!("send address must be i64 in @{}", f.name);
            }
        }
        Op::ConsumeVal { chan, ty: want, .. } => {
            if m.array(m.chan(*chan).arr).elem != *want {
                bail!("consume_val type mismatch in @{}", f.name);
            }
        }
        Op::ProduceVal { chan, val, .. } => {
            if ty(*val) != m.array(m.chan(*chan).arr).elem {
                bail!("produce_val type mismatch in @{}", f.name);
            }
        }
        _ => {}
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_module;

    #[test]
    fn verifies_wellformed() {
        let src = r#"
array @A : i64[10]
func @f(%n: i64) {
entry:
  %c0 = const.i 0
  br header
header:
  %i = phi i64 [entry: %c0], [body: %inext]
  %c = icmp.lt %i, %n
  condbr %c, body, exit
body:
  %v = load @A[%i]
  store @A[%i], %v
  %c1 = const.i 1
  %inext = add.i %i, %c1
  br header
exit:
  ret
}
"#;
        let m = parse_module(src).unwrap();
        verify_module(&m).unwrap();
    }

    #[test]
    fn rejects_use_not_dominating() {
        // Built via the builder because the parser rejects forward value
        // references syntactically: condbr in `entry` uses a value defined
        // only in `b`, which does not dominate `entry`.
        use crate::ir::{CmpOp, FunctionBuilder, Type};
        let mut b = FunctionBuilder::new("f");
        let n = b.param("n", Type::I64);
        let (entry, ba, bb, exit) =
            (b.block("entry"), b.block("a"), b.block("b"), b.block("exit"));
        b.switch_to(bb);
        let c = b.icmp(CmpOp::Lt, n, n);
        b.br(exit);
        b.switch_to(entry);
        b.cond_br(c, ba, bb);
        b.switch_to(ba);
        b.br(exit);
        b.switch_to(exit);
        b.ret();
        let f = b.finish();
        let m = Module::new();
        assert!(verify_function(&m, &f).is_err());
    }

    #[test]
    fn rejects_irreducible() {
        // entry branches into both halves of an a <-> b cycle: the
        // retreating edge b -> a targets a block that does not dominate
        // its source, so no natural-loop decomposition exists.
        use crate::ir::{CmpOp, FunctionBuilder, Type};
        let mut bld = FunctionBuilder::new("irr");
        let n = bld.param("n", Type::I64);
        let (entry, ba, bb) = (bld.block("entry"), bld.block("a"), bld.block("b"));
        bld.switch_to(entry);
        let c = bld.icmp(CmpOp::Lt, n, n);
        bld.cond_br(c, ba, bb);
        bld.switch_to(ba);
        bld.br(bb);
        bld.switch_to(bb);
        bld.br(ba);
        let f = bld.finish();
        let m = Module::new();
        let err = verify_function(&m, &f).unwrap_err().to_string();
        assert!(err.contains("irreducible"), "unexpected error: {err}");
        assert!(err.contains("b -> a") || err.contains("a -> b"), "edge not named: {err}");
    }

    #[test]
    fn rejects_unterminated() {
        let src = r#"
func @f() {
entry:
  %c0 = const.i 0
}
"#;
        // parser leaves the block unterminated
        let m = parse_module(src).unwrap();
        assert!(verify_module(&m).is_err());
    }
}
