//! Value types. The IR is intentionally small: 64-bit integers (also used
//! for addresses/indices), 64-bit floats, and booleans (branch conditions,
//! predicates, poison bits).

use std::fmt;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Type {
    I64,
    F64,
    B1,
}

impl Type {
    pub fn is_numeric(self) -> bool {
        matches!(self, Type::I64 | Type::F64)
    }
}

impl fmt::Display for Type {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Type::I64 => write!(f, "i64"),
            Type::F64 => write!(f, "f64"),
            Type::B1 => write!(f, "b1"),
        }
    }
}

/// A runtime value (interpreter + simulator).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Val {
    I(i64),
    F(f64),
    B(bool),
}

impl Val {
    pub fn ty(self) -> Type {
        match self {
            Val::I(_) => Type::I64,
            Val::F(_) => Type::F64,
            Val::B(_) => Type::B1,
        }
    }

    pub fn as_i(self) -> i64 {
        match self {
            Val::I(x) => x,
            Val::F(x) => x as i64,
            Val::B(b) => b as i64,
        }
    }

    pub fn as_f(self) -> f64 {
        match self {
            Val::I(x) => x as f64,
            Val::F(x) => x,
            Val::B(b) => b as u8 as f64,
        }
    }

    pub fn as_b(self) -> bool {
        match self {
            Val::I(x) => x != 0,
            Val::F(x) => x != 0.0,
            Val::B(b) => b,
        }
    }

    /// Bit-exact equality for memory comparison (NaN == NaN).
    pub fn bits_eq(self, other: Val) -> bool {
        match (self, other) {
            (Val::I(a), Val::I(b)) => a == b,
            (Val::F(a), Val::F(b)) => a.to_bits() == b.to_bits(),
            (Val::B(a), Val::B(b)) => a == b,
            _ => false,
        }
    }

    pub fn zero(ty: Type) -> Val {
        match ty {
            Type::I64 => Val::I(0),
            Type::F64 => Val::F(0.0),
            Type::B1 => Val::B(false),
        }
    }
}

impl fmt::Display for Val {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Val::I(x) => write!(f, "{x}"),
            Val::F(x) => write!(f, "{x}"),
            Val::B(b) => write!(f, "{b}"),
        }
    }
}
