//! A small SSA intermediate representation.
//!
//! The IR is deliberately close to what the paper's LLVM passes operate on,
//! while staying array-based (no raw pointers): memory is a set of named,
//! statically sized arrays, and `load`/`store` take an array plus an `i64`
//! index. This matches the paper's `A[idx[i]]`-style irregular kernels and
//! makes memory disambiguation in the simulated load-store queue exact.
//!
//! Design notes:
//! - Dense `u32` ids everywhere ([`ValueId`], [`BlockId`], [`InstrId`],
//!   [`ArrayId`], [`ChanId`]) indexing flat arenas — the hot paths
//!   (simulator, path enumeration) never hash.
//! - Instructions live in a per-function arena; blocks hold `Vec<InstrId>`
//!   so the CFG transforms (hoisting, poison-block insertion, merging) are
//!   cheap id shuffles.
//! - DAE channel intrinsics are first-class ops so the decoupled slices
//!   remain verifiable, printable and interpretable IR.

pub mod builder;
pub mod ops;
pub mod parser;
pub mod printer;
pub mod types;
pub mod verify;

pub use builder::FunctionBuilder;
pub use ops::{BinOp, ChanKind, CmpOp, Op, Terminator};
pub use types::Type;

use std::fmt;

macro_rules! id_type {
    ($(#[$doc:meta])* $name:ident, $prefix:expr) => {
        $(#[$doc])*
        #[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
        pub struct $name(pub u32);

        impl $name {
            #[inline]
            pub fn index(self) -> usize {
                self.0 as usize
            }
        }

        impl fmt::Debug for $name {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, concat!($prefix, "{}"), self.0)
            }
        }

        impl fmt::Display for $name {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, concat!($prefix, "{}"), self.0)
            }
        }
    };
}

id_type!(
    /// Index into [`Function::values`].
    ValueId, "%v"
);
id_type!(
    /// Index into [`Function::blocks`].
    BlockId, "bb"
);
id_type!(
    /// Index into [`Function::instrs`].
    InstrId, "i"
);
id_type!(
    /// Index into [`Module::arrays`].
    ArrayId, "@a"
);
id_type!(
    /// Index into [`Module::chans`]. One channel per decoupled static
    /// memory operation and direction (see [`ops::ChanKind`]).
    ChanId, "ch"
);

/// How a [`ValueId`] is defined.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ValueDef {
    /// The n-th function parameter.
    Param(u32),
    /// The result of an instruction.
    Instr(InstrId),
}

/// Metadata for one SSA value.
#[derive(Clone, Debug)]
pub struct ValueInfo {
    pub def: ValueDef,
    pub ty: Type,
    /// Optional source-level name, used by the printer (`%name`).
    pub name: Option<String>,
}

/// One instruction in the arena. Detached instructions (removed from a
/// block by DCE or hoisting without being re-inserted) simply stop being
/// referenced; the arena is never compacted.
#[derive(Clone, Debug)]
pub struct Instr {
    pub op: Op,
    /// `Some` iff the op produces a value.
    pub result: Option<ValueId>,
}

/// A basic block: straight-line instructions plus one terminator.
#[derive(Clone, Debug)]
pub struct Block {
    pub name: String,
    pub instrs: Vec<InstrId>,
    pub term: Terminator,
}

/// A declared memory array (the unit of disambiguation in the LSQ).
#[derive(Clone, Debug)]
pub struct ArrayDecl {
    pub name: String,
    pub elem: Type,
    pub size: usize,
}

/// A FIFO channel connecting two units of the decoupled machine.
///
/// Channels are declared at module level so the AGU and CU slices (two
/// separate functions) can refer to the same channel.
#[derive(Clone, Debug)]
pub struct ChanDecl {
    pub kind: ChanKind,
    /// Array this channel's requests/values refer to. Each (array, kind)
    /// pair has at most one channel: all static memory ops on the same
    /// array share one request stream and one value stream — which is
    /// exactly why the paper's ordering problem (§2) exists. Individual
    /// static ops are identified by the `mem` tag on the intrinsics.
    pub arr: ArrayId,
}

/// A function: parameters, an entry block, and arenas of blocks,
/// instructions and values.
#[derive(Clone, Debug)]
pub struct Function {
    pub name: String,
    pub params: Vec<ValueId>,
    pub blocks: Vec<Block>,
    pub instrs: Vec<Instr>,
    pub values: Vec<ValueInfo>,
    pub entry: BlockId,
}

impl Default for Function {
    fn default() -> Self {
        Function {
            name: String::new(),
            params: Vec::new(),
            blocks: Vec::new(),
            instrs: Vec::new(),
            values: Vec::new(),
            entry: BlockId(0),
        }
    }
}

/// A module: arrays + channels + functions.
///
/// The original program is a single function; after decoupling (§3.2) the
/// module holds the `agu` and `cu` slices plus the shared channel table.
#[derive(Clone, Debug, Default)]
pub struct Module {
    pub arrays: Vec<ArrayDecl>,
    pub chans: Vec<ChanDecl>,
    pub funcs: Vec<Function>,
}

impl Module {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_array(&mut self, name: &str, elem: Type, size: usize) -> ArrayId {
        let id = ArrayId(self.arrays.len() as u32);
        self.arrays.push(ArrayDecl { name: name.to_string(), elem, size });
        id
    }

    /// Get or create the channel for `(kind, arr)`.
    pub fn add_chan(&mut self, kind: ChanKind, arr: ArrayId) -> ChanId {
        if let Some(i) = self.chans.iter().position(|c| c.kind == kind && c.arr == arr) {
            return ChanId(i as u32);
        }
        let id = ChanId(self.chans.len() as u32);
        self.chans.push(ChanDecl { kind, arr });
        id
    }

    pub fn array(&self, id: ArrayId) -> &ArrayDecl {
        &self.arrays[id.index()]
    }

    pub fn chan(&self, id: ChanId) -> &ChanDecl {
        &self.chans[id.index()]
    }

    pub fn func_by_name(&self, name: &str) -> Option<&Function> {
        self.funcs.iter().find(|f| f.name == name)
    }
}

impl Function {
    pub fn new(name: &str) -> Self {
        Function { name: name.to_string(), ..Default::default() }
    }

    pub fn add_param(&mut self, name: &str, ty: Type) -> ValueId {
        let idx = self.params.len() as u32;
        let v = self.new_value(ValueDef::Param(idx), ty, Some(name.to_string()));
        self.params.push(v);
        v
    }

    pub fn new_value(&mut self, def: ValueDef, ty: Type, name: Option<String>) -> ValueId {
        let id = ValueId(self.values.len() as u32);
        self.values.push(ValueInfo { def, ty, name });
        id
    }

    pub fn new_block(&mut self, name: &str) -> BlockId {
        let id = BlockId(self.blocks.len() as u32);
        self.blocks.push(Block {
            name: name.to_string(),
            instrs: Vec::new(),
            term: Terminator::Unterminated,
        });
        id
    }

    /// Append a fresh instruction to `bb`, returning its result value (if
    /// the op produces one).
    pub fn push_instr(&mut self, bb: BlockId, op: Op) -> Option<ValueId> {
        let iid = self.create_instr(op);
        self.blocks[bb.index()].instrs.push(iid);
        self.instrs[iid.index()].result
    }

    /// Create an instruction in the arena without inserting it anywhere.
    pub fn create_instr(&mut self, op: Op) -> InstrId {
        let iid = InstrId(self.instrs.len() as u32);
        let result = op
            .result_type()
            .map(|ty| self.new_value(ValueDef::Instr(iid), ty, None));
        self.instrs.push(Instr { op, result });
        iid
    }

    pub fn instr(&self, id: InstrId) -> &Instr {
        &self.instrs[id.index()]
    }

    pub fn instr_mut(&mut self, id: InstrId) -> &mut Instr {
        &mut self.instrs[id.index()]
    }

    pub fn block(&self, id: BlockId) -> &Block {
        &self.blocks[id.index()]
    }

    pub fn block_mut(&mut self, id: BlockId) -> &mut Block {
        &mut self.blocks[id.index()]
    }

    pub fn value(&self, id: ValueId) -> &ValueInfo {
        &self.values[id.index()]
    }

    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Successors of a block (0, 1 or 2).
    pub fn succs(&self, bb: BlockId) -> Vec<BlockId> {
        self.blocks[bb.index()].term.succs()
    }

    /// Predecessor lists for every block. O(V+E); recompute after CFG edits.
    pub fn preds(&self) -> Vec<Vec<BlockId>> {
        let mut preds = vec![Vec::new(); self.blocks.len()];
        for (i, b) in self.blocks.iter().enumerate() {
            for s in b.term.succs() {
                preds[s.index()].push(BlockId(i as u32));
            }
        }
        preds
    }

    /// The block that contains `iid`, if any (linear scan; fine off the
    /// hot path, transforms cache their own maps).
    pub fn block_of_instr(&self, iid: InstrId) -> Option<BlockId> {
        for (i, b) in self.blocks.iter().enumerate() {
            if b.instrs.contains(&iid) {
                return Some(BlockId(i as u32));
            }
        }
        None
    }

    /// Split the `from -> to` CFG edge, inserting and returning a fresh
    /// block. Rewrites `from`'s terminator and `to`'s φ incoming labels.
    pub fn split_edge(&mut self, from: BlockId, to: BlockId, name: &str) -> BlockId {
        let nb = self.new_block(name);
        self.blocks[nb.index()].term = Terminator::Br(to);
        self.blocks[from.index()].term.replace_succ(to, nb);
        // φs in `to` that named `from` as an incoming block now arrive via
        // `nb`.
        let to_instrs = self.blocks[to.index()].instrs.clone();
        for iid in to_instrs {
            if let Op::Phi { incomings: ref mut inc, .. } = self.instrs[iid.index()].op {
                for (bb, _) in inc.iter_mut() {
                    if *bb == from {
                        *bb = nb;
                    }
                }
            }
        }
        nb
    }

    /// Replace every use of `old` with `new` in all instructions and
    /// terminators.
    pub fn replace_all_uses(&mut self, old: ValueId, new: ValueId) {
        for instr in &mut self.instrs {
            instr.op.replace_use(old, new);
        }
        for b in &mut self.blocks {
            if let Terminator::CondBr { cond, .. } = &mut b.term {
                if *cond == old {
                    *cond = new;
                }
            }
        }
    }
}
