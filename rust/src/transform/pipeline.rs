//! Pass pipelines for the four evaluated architectures (paper §8.1.1):
//!
//! - **STA** — the original function, simulated with static-schedule
//!   memory semantics (in-order ambiguous loads).
//! - **DAE** — §3.2 decoupling, no speculation: LoD branches synchronise
//!   the AGU on DU values.
//! - **SPEC** — DAE + the paper's contribution: Algorithm 1 hoisting,
//!   Algorithms 2+3 poisoning, §5.3 merging, §5.4 speculative loads.
//! - **ORACLE** — LoD removed from the input (wrong results, perf bound),
//!   then plain DAE.

use super::decouple::{decouple, refresh_consumes, DaeProgram};
use super::hoist::{hoist_speculative_requests, SpecReqMap};
use super::poison::{place_poisons, PoisonStats};
use super::{dce, merge_poison, oracle, simplify_cfg, spec_load};
use crate::analysis::{DomTree, LodAnalysis, LoopInfo, Reachability};
use crate::ir::{Function, Module};
use crate::sim::decoded::{decode_fns, DecodedSim};
use anyhow::Result;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Arch {
    Sta,
    Dae,
    Spec,
    Oracle,
}

impl Arch {
    pub const ALL: [Arch; 4] = [Arch::Sta, Arch::Dae, Arch::Spec, Arch::Oracle];

    pub fn name(self) -> &'static str {
        match self {
            Arch::Sta => "STA",
            Arch::Dae => "DAE",
            Arch::Spec => "SPEC",
            Arch::Oracle => "ORACLE",
        }
    }
}

/// Per-build statistics feeding Table 1 and Fig. 7.
#[derive(Clone, Debug, Default)]
pub struct BuildStats {
    pub poison_blocks: usize,
    pub poison_calls: usize,
    pub merged_blocks: usize,
    pub refused: Vec<(u32, String)>,
    pub spec_loads_moved: usize,
}

/// A compiled architecture: either a monolithic function (STA) or a
/// decoupled program (DAE/SPEC/ORACLE). Both carry the pre-decoded
/// simulator image ([`DecodedSim`]) built once here, so every
/// `simulate` call starts from flat instruction streams and dense
/// channel ids.
pub enum Compiled {
    Monolithic { module: Module, arch: Arch, decoded: DecodedSim },
    Dae {
        program: DaeProgram,
        arch: Arch,
        map: Option<SpecReqMap>,
        stats: BuildStats,
        decoded: DecodedSim,
    },
}

impl Compiled {
    pub fn arch(&self) -> Arch {
        match self {
            Compiled::Monolithic { arch, .. } => *arch,
            Compiled::Dae { arch, .. } => *arch,
        }
    }

    pub fn stats(&self) -> Option<&BuildStats> {
        match self {
            Compiled::Monolithic { .. } => None,
            Compiled::Dae { stats, .. } => Some(stats),
        }
    }

    /// Memory-op ids of speculatively hoisted *stores* — the ops whose
    /// request/poison traffic the simulator attributes to speculation
    /// (mis-speculation-rate accounting, fault-storm targeting).
    pub fn speculated_mems(&self) -> Vec<u32> {
        match self {
            Compiled::Monolithic { .. } => Vec::new(),
            Compiled::Dae { map, .. } => map
                .as_ref()
                .map(|m| {
                    m.iter()
                        .flat_map(|(_, rs)| rs.iter().filter(|r| r.is_store).map(|r| r.mem))
                        .collect()
                })
                .unwrap_or_default(),
        }
    }

    /// Memory-op ids of speculatively hoisted *loads* (§5.4) — the
    /// metrics layer attributes their request traffic to speculation.
    pub fn speculated_load_mems(&self) -> Vec<u32> {
        match self {
            Compiled::Monolithic { .. } => Vec::new(),
            Compiled::Dae { map, .. } => map
                .as_ref()
                .map(|m| {
                    m.iter()
                        .flat_map(|(_, rs)| rs.iter().filter(|r| !r.is_store).map(|r| r.mem))
                        .collect()
                })
                .unwrap_or_default(),
        }
    }
}

/// Compile `(m, f)` — `f` must be `m.funcs[func_idx]` — for `arch`.
///
/// Debug builds additionally run the semantic linter (`crate::lint`) on
/// the result, the way `verify_module` already runs inside each arm:
/// any Error-severity diagnostic fails the build.
pub fn build(m: &Module, func_idx: usize, arch: Arch) -> Result<Compiled> {
    let compiled = build_unchecked(m, func_idx, arch)?;
    #[cfg(debug_assertions)]
    {
        let rep = crate::lint::lint_compiled(m, func_idx, &compiled);
        if rep.has_errors() {
            anyhow::bail!(
                "semantic lint failed after {} build:\n{}",
                arch.name(),
                rep.render(crate::lint::Severity::Error)
            );
        }
    }
    Ok(compiled)
}

fn build_unchecked(m: &Module, func_idx: usize, arch: Arch) -> Result<Compiled> {
    let f = &m.funcs[func_idx];
    match arch {
        Arch::Sta => {
            let module = Module {
                arrays: m.arrays.clone(),
                chans: vec![],
                funcs: vec![f.clone()],
            };
            let decoded = decode_fns(&module, &[0])?;
            Ok(Compiled::Monolithic { module, arch, decoded })
        }
        Arch::Dae => {
            let mut p = decouple(m, f, true);
            simplify_cfg::run(&mut p.module.funcs[0]);
            simplify_cfg::run(&mut p.module.funcs[1]);
            refresh_consumes(&mut p);
            crate::ir::verify::verify_module(&p.module)?;
            let decoded = decode_fns(&p.module, &[p.agu, p.cu])?;
            Ok(Compiled::Dae {
                program: p,
                arch,
                map: None,
                stats: BuildStats::default(),
                decoded,
            })
        }
        Arch::Spec => {
            let lod = LodAnalysis::new(m, f);
            let dom = DomTree::new(f);
            let loops = LoopInfo::new(f, &dom);
            let reach = Reachability::new(f, &dom);
            let mut p = decouple(m, f, false);
            let hr = hoist_speculative_requests(&mut p, &lod, &dom, &loops, &reach);
            let pstats: PoisonStats = place_poisons(&mut p, &hr.map)?;
            let moved = spec_load::hoist_spec_load_consumes(&mut p, &hr.map);
            let agu_idx = p.agu;
            let cu_idx = p.cu;
            dce::run(&mut p.module.funcs[agu_idx]);
            dce::run(&mut p.module.funcs[cu_idx]);
            let merged = merge_poison::run(&mut p.module.funcs[cu_idx]);
            // simplify + a second DCE round: folding the emptied guard
            // branch (condbr with identical targets) kills the guard
            // condition and, in the AGU, the consume feeding it — that
            // final cut is what restores full decoupling.
            for fi in [agu_idx, cu_idx] {
                simplify_cfg::run(&mut p.module.funcs[fi]);
                dce::run(&mut p.module.funcs[fi]);
                simplify_cfg::run(&mut p.module.funcs[fi]);
            }
            refresh_consumes(&mut p);
            crate::ir::verify::verify_module(&p.module)?;
            let stats = BuildStats {
                poison_blocks: pstats.poison_blocks.saturating_sub(merged),
                poison_calls: pstats.poison_calls,
                merged_blocks: merged,
                refused: hr.refused.clone(),
                spec_loads_moved: moved,
            };
            let decoded = decode_fns(&p.module, &[p.agu, p.cu])?;
            Ok(Compiled::Dae { program: p, arch, map: Some(hr.map), stats, decoded })
        }
        Arch::Oracle => {
            let (of, skipped) = oracle::flatten_lod(m, f);
            let mut p = decouple(m, &of, true);
            simplify_cfg::run(&mut p.module.funcs[0]);
            simplify_cfg::run(&mut p.module.funcs[1]);
            refresh_consumes(&mut p);
            crate::ir::verify::verify_module(&p.module)?;
            let stats = BuildStats {
                refused: if skipped > 0 {
                    vec![(u32::MAX, format!("{skipped} ops kept guarded"))]
                } else {
                    vec![]
                },
                ..Default::default()
            };
            let decoded = decode_fns(&p.module, &[p.agu, p.cu])?;
            Ok(Compiled::Dae { program: p, arch, map: None, stats, decoded })
        }
    }
}

/// Convenience: the single function of a monolithic build.
pub fn mono_fn(c: &Compiled) -> Option<&Function> {
    match c {
        Compiled::Monolithic { module, .. } => Some(&module.funcs[0]),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_module;

    #[test]
    fn all_archs_build_fig1c() {
        let src = r#"
array @A : i64[100]
array @idx : i64[100]

func @fig1c(%n: i64) {
entry:
  %c0 = const.i 0
  br header
header:
  %i = phi i64 [entry: %c0], [latch: %inext]
  %cc = icmp.lt %i, %n
  condbr %cc, body, exit
body:
  %a = load @A[%i]
  %zero = const.i 0
  %p = icmp.gt %a, %zero
  condbr %p, then, latch
then:
  %w = load @idx[%i]
  %aw = load @A[%w]
  %c1 = const.i 1
  %fv = add.i %aw, %c1
  store @A[%w], %fv
  br latch
latch:
  %c1b = const.i 1
  %inext = add.i %i, %c1b
  br header
exit:
  ret
}
"#;
        let m = parse_module(src).unwrap();
        for arch in Arch::ALL {
            let c = build(&m, 0, arch).unwrap_or_else(|e| panic!("{arch:?}: {e}"));
            if let Compiled::Dae { stats, .. } = &c {
                if arch == Arch::Spec {
                    assert_eq!(stats.poison_calls, 1, "fig1c has one poisoned store");
                }
            }
        }
    }
}
