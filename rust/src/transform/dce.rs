//! Dead-code elimination (paper §3.2 step 3).
//!
//! Roots are side-effecting ops (stores, channel sends/produces/poisons)
//! and terminator conditions. [`Op::ConsumeVal`] is special: although it
//! pops a FIFO, a consume whose *result is unused* is removable — the
//! stream contract is renegotiated afterwards (the DU simply stops
//! forwarding values for that static op), which is how the AGU slice
//! sheds the loads it does not need (see `decouple::prune_channels`).

use crate::ir::{Function, InstrId, Op, Terminator};

/// Returns the set of removed instruction ids.
pub fn run(f: &mut Function) -> Vec<InstrId> {
    // Count uses of each value by live instructions, iterating to a fixed
    // point: start by assuming everything is live, then peel dead ops.
    let mut live = vec![false; f.instrs.len()];
    let mut work: Vec<InstrId> = Vec::new();

    // Roots: side effects (minus consumes) + terminators.
    for (bi, b) in f.blocks.iter().enumerate() {
        let _ = bi;
        for &iid in &b.instrs {
            let op = &f.instr(iid).op;
            let is_root = match op {
                Op::ConsumeVal { .. } => false, // removable if result unused
                op => op.has_side_effect(),
            };
            if is_root && !live[iid.index()] {
                live[iid.index()] = true;
                work.push(iid);
            }
        }
    }
    // Terminator conditions are roots.
    let mut root_values: Vec<crate::ir::ValueId> = Vec::new();
    for b in &f.blocks {
        if let Terminator::CondBr { cond, .. } = b.term {
            root_values.push(cond);
        }
    }

    let def_instr = |f: &Function, v: crate::ir::ValueId| -> Option<InstrId> {
        match f.value(v).def {
            crate::ir::ValueDef::Instr(i) => Some(i),
            _ => None,
        }
    };

    for v in root_values {
        if let Some(iid) = def_instr(f, v) {
            if !live[iid.index()] {
                live[iid.index()] = true;
                work.push(iid);
            }
        }
    }

    while let Some(iid) = work.pop() {
        for v in f.instr(iid).op.uses() {
            if let Some(d) = def_instr(f, v) {
                if !live[d.index()] {
                    live[d.index()] = true;
                    work.push(d);
                }
            }
        }
    }

    // Remove dead instructions from blocks.
    let mut removed = Vec::new();
    for b in &mut f.blocks {
        b.instrs.retain(|&iid| {
            // Instructions in blocks but not in the arena range guard.
            let keep = live[iid.index()];
            if !keep {
                removed.push(iid);
            }
            keep
        });
    }
    removed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_single;

    #[test]
    fn removes_dead_arith_keeps_stores() {
        let (_m, mut f) = parse_single(
            r#"
array @A : i64[8]
func @f(%n: i64) {
entry:
  %c1 = const.i 1
  %dead = add.i %n, %c1
  %dead2 = mul.i %dead, %dead
  %live = add.i %n, %n
  store @A[%c1], %live
  ret
}
"#,
        )
        .unwrap();
        let removed = run(&mut f);
        assert_eq!(removed.len(), 2);
        assert_eq!(f.blocks[0].instrs.len(), 3);
    }

    #[test]
    fn unused_consume_removed_used_consume_kept() {
        let (_m, mut f) = parse_single(
            r#"
array @A : i64[8]
chan ch0 : ld_val @A
chan ch1 : st_val @A
func @cu() {
entry:
  %v = consume_val ch0:m0
  %w = consume_val ch0:m1
  produce_val ch1:m2, %w
  ret
}
"#,
        )
        .unwrap();
        let removed = run(&mut f);
        assert_eq!(removed.len(), 1, "only the unused consume dies");
        assert!(matches!(
            f.instr(f.blocks[0].instrs[0]).op,
            Op::ConsumeVal { mem: 1, .. }
        ));
    }

    #[test]
    fn keeps_branch_condition_chain() {
        let (_m, mut f) = parse_single(
            r#"
func @f(%n: i64) {
entry:
  %c1 = const.i 1
  %x = add.i %n, %c1
  %c = icmp.lt %x, %n
  condbr %c, a, b
a:
  br b
b:
  ret
}
"#,
        )
        .unwrap();
        let removed = run(&mut f);
        assert!(removed.is_empty());
    }
}
