//! §5.4 — speculative load consumption.
//!
//! When Algorithm 1 hoists a `send_ld_addr` in the AGU, the matching
//! `consume_val` in the CU must move to the corresponding block so the
//! per-op value stream stays balanced (one consume per send on every
//! path). The hoist pass only speculates loads with a *single, dominating*
//! spec source, so re-homing the consume preserves SSA dominance for all
//! existing uses; on mis-speculated paths the value is simply unused
//! (§5.4: "the CU can either use the load value or discard it").

use super::decouple::DaeProgram;
use super::hoist::SpecReqMap;
use crate::ir::Op;

/// Move CU consumes of speculated loads to their spec blocks. Returns the
/// number of consumes moved.
pub fn hoist_spec_load_consumes(p: &mut DaeProgram, map: &SpecReqMap) -> usize {
    let cu_idx = p.cu;
    let cu = &mut p.module.funcs[cu_idx];
    let mut moved = 0;

    for (spec_bb, reqs) in map {
        for r in reqs {
            if r.is_store {
                continue;
            }
            // find the CU consume with this mem tag
            let mut found = None;
            'outer: for (bi, b) in cu.blocks.iter().enumerate() {
                for (pos, &iid) in b.instrs.iter().enumerate() {
                    if let Op::ConsumeVal { mem, .. } = cu.instr(iid).op {
                        if mem == r.mem {
                            found = Some((bi, pos, iid));
                            break 'outer;
                        }
                    }
                }
            }
            let Some((bi, pos, iid)) = found else {
                continue; // already DCE'd (value unused in CU) — nothing to balance
            };
            if bi == spec_bb.index() {
                continue; // already there
            }
            cu.blocks[bi].instrs.remove(pos);
            cu.blocks[spec_bb.index()].instrs.push(iid);
            moved += 1;
        }
    }
    moved
}

#[cfg(test)]
mod tests {
    use crate::analysis::{DomTree, LodAnalysis, LoopInfo, Reachability};
    use crate::ir::parser::parse_single;
    use crate::ir::Op;
    use crate::transform::decouple::decouple;
    use crate::transform::hoist::hoist_speculative_requests;

    #[test]
    fn consume_moves_with_send() {
        // guarded load whose value feeds compute (kept in CU) — the CU
        // consume must follow the hoisted send to `body`.
        let (m, f) = parse_single(
            r#"
array @A : i64[100]
array @B : i64[100]

func @specload(%n: i64) {
entry:
  %c0 = const.i 0
  br header
header:
  %i = phi i64 [entry: %c0], [latch: %inext]
  %cc = icmp.lt %i, %n
  condbr %cc, body, exit
body:
  %a = load @A[%i]
  %p = icmp.gt %a, %c0
  condbr %p, then, latch
then:
  %b = load @B[%i]
  %s = add.i %a, %b
  store @A[%i], %s
  br latch
latch:
  %c1 = const.i 1
  %inext = add.i %i, %c1
  br header
exit:
  ret
}
"#,
        )
        .unwrap();
        let lod = LodAnalysis::new(&m, &f);
        let dom = DomTree::new(&f);
        let loops = LoopInfo::new(&f, &dom);
        let reach = Reachability::new(&f, &dom);
        let mut p = decouple(&m, &f, false);
        let hr = hoist_speculative_requests(&mut p, &lod, &dom, &loops, &reach);
        assert!(hr.refused.is_empty(), "{:?}", hr.refused);
        let moved = super::hoist_spec_load_consumes(&mut p, &hr.map);
        assert!(moved >= 1, "B-load consume should move to body");
        // the consume of B now lives in `body`
        let cu = p.cu_fn();
        let body = &cu.blocks[2];
        let has_b_consume = body.instrs.iter().any(|&iid| {
            matches!(cu.instr(iid).op, Op::ConsumeVal { mem, .. }
                if p.mem_ops[mem as usize].arr.0 == 1)
        });
        assert!(has_b_consume);
        crate::ir::verify::verify_module(&p.module).unwrap();
    }
}
