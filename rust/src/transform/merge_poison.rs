//! §5.3 — merging poison blocks.
//!
//! Two poison blocks can merge when they contain the same list of poison
//! calls and share the same immediate successor; predecessors of the
//! duplicate retarget to the representative and the duplicate is
//! detached. φs in the common successor must agree between the two arms
//! (they do for pure poison blocks, which define nothing).

use crate::ir::{BlockId, Function, Op, Terminator};

/// Merge equivalent poison blocks in `f`; returns the number of blocks
/// removed. `is_poison_block` selects candidates (by construction their
/// names start with `poison_`).
pub fn run(f: &mut Function) -> usize {
    let mut removed = 0;
    loop {
        let reach = crate::transform::simplify_cfg::reachable_blocks(f);
        let candidates: Vec<BlockId> = (0..f.num_blocks() as u32)
            .map(BlockId)
            .filter(|b| reach[b.index()] && f.block(*b).name.starts_with("poison_"))
            .collect();

        let mut merged_this_round = false;
        'outer: for i in 0..candidates.len() {
            for j in i + 1..candidates.len() {
                let (a, b) = (candidates[i], candidates[j]);
                if !mergeable(f, a, b) {
                    continue;
                }
                // retarget b's preds to a
                let preds = f.preds();
                for &p in &preds[b.index()] {
                    f.block_mut(p).term.replace_succ(b, a);
                }
                // fix φs in the common successor: drop the arm for b
                if let Terminator::Br(succ) = f.block(a).term {
                    let instrs = f.block(succ).instrs.clone();
                    for iid in instrs {
                        if let Op::Phi { incomings, .. } = &mut f.instr_mut(iid).op {
                            incomings.retain(|(bb, _)| *bb != b);
                        }
                    }
                }
                f.block_mut(b).instrs.clear();
                f.block_mut(b).term = Terminator::Ret;
                removed += 1;
                merged_this_round = true;
                break 'outer;
            }
        }
        if !merged_this_round {
            break;
        }
    }
    removed
}

fn mergeable(f: &Function, a: BlockId, b: BlockId) -> bool {
    let (ba, bb) = (f.block(a), f.block(b));
    // same single successor
    let (Terminator::Br(sa), Terminator::Br(sb)) = (&ba.term, &bb.term) else {
        return false;
    };
    if sa != sb {
        return false;
    }
    // identical poison call lists (chan, mem, pred)
    if ba.instrs.len() != bb.instrs.len() {
        return false;
    }
    for (&ia, &ib) in ba.instrs.iter().zip(&bb.instrs) {
        match (&f.instr(ia).op, &f.instr(ib).op) {
            (
                Op::PoisonVal { chan: c1, mem: m1, pred: p1 },
                Op::PoisonVal { chan: c2, mem: m2, pred: p2 },
            ) if c1 == c2 && m1 == m2 && p1 == p2 => {}
            _ => return false,
        }
    }
    // φs in the successor must agree for arms a and b
    for &iid in &f.block(*sa).instrs {
        if let Op::Phi { incomings, .. } = &f.instr(iid).op {
            let va = incomings.iter().find(|(bb2, _)| *bb2 == a).map(|(_, v)| *v);
            let vb = incomings.iter().find(|(bb2, _)| *bb2 == b).map(|(_, v)| *v);
            if va != vb {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_single;

    #[test]
    fn merges_identical_poison_blocks() {
        let (_m, mut f) = parse_single(
            r#"
array @A : i64[8]
chan ch0 : st_val @A

func @f(%c: b1) {
entry:
  condbr %c, poison_a, poison_b
poison_a:
  poison_val ch0:m1
  poison_val ch0:m2
  br join
poison_b:
  poison_val ch0:m1
  poison_val ch0:m2
  br join
join:
  ret
}
"#,
        )
        .unwrap();
        let removed = run(&mut f);
        assert_eq!(removed, 1);
        let n = crate::transform::simplify_cfg::num_reachable_blocks(&f);
        assert_eq!(n, 3);
    }

    #[test]
    fn different_lists_do_not_merge() {
        let (_m, mut f) = parse_single(
            r#"
array @A : i64[8]
chan ch0 : st_val @A

func @f(%c: b1) {
entry:
  condbr %c, poison_a, poison_b
poison_a:
  poison_val ch0:m1
  br join
poison_b:
  poison_val ch0:m2
  br join
join:
  ret
}
"#,
        )
        .unwrap();
        assert_eq!(run(&mut f), 0);
    }
}
