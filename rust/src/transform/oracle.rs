//! ORACLE construction — paper §8.1.1.
//!
//! "The same as DAE, but all LoD control dependencies are removed
//! manually from the input code. The ORACLE results are wrong, but give a
//! bound on the performance of SPEC and show its area overhead."
//!
//! We mechanise the manual edit: every memory op with a control LoD is
//! moved (with its full operand slice, *including loads*) to its chain-
//! head source block, making it unconditional. The resulting function
//! then decouples with no loss of decoupling. Functional results differ
//! from the original program wherever the guard would have been false —
//! by design.

use crate::analysis::{DomTree, LodAnalysis, LoopInfo, Reachability};
use crate::ir::{Function, InstrId, Module, Op, ValueDef, ValueId};
use std::collections::{HashMap, HashSet};

/// Flatten LoD control dependencies in `f`. Returns the rewritten
/// function and the number of ops it could not flatten (left guarded).
pub fn flatten_lod(m: &Module, f: &Function) -> (Function, usize) {
    let mut out = f.clone();
    out.name = format!("{}__oracle", f.name);
    let lod = LodAnalysis::new(m, f);
    let dom = DomTree::new(f);
    let loops = LoopInfo::new(f, &dom);
    let reach = Reachability::new(f, &dom);
    let _ = reach;

    let mut skipped = 0usize;

    // plan: (memory op instr, target chain head)
    let mut plan: Vec<(InstrId, crate::ir::BlockId)> = Vec::new();
    for &src in &lod.chain_heads {
        let (region, enters_inner) = super::hoist::spec_region(f, src, &dom, &loops);
        if enters_inner {
            skipped += 1;
            continue;
        }
        for &bb in &region {
            if bb == src {
                continue;
            }
            for &iid in &f.block(bb).instrs {
                if f.instr(iid).op.is_memory() && !plan.iter().any(|(i, _)| *i == iid) {
                    plan.push((iid, src));
                }
            }
        }
    }

    for (iid, src) in plan {
        // full operand slice (loads allowed — ORACLE accepts wrong values)
        let roots: Vec<ValueId> = out.instr(iid).op.uses();
        let Some(slice) = clone_slice_with_loads(&out, &roots, src, &dom) else {
            skipped += 1;
            continue;
        };
        let mut remap: HashMap<ValueId, ValueId> = HashMap::new();
        for s in slice {
            let mut op = out.instr(s).op.clone();
            for (o, n) in &remap {
                op.replace_use(*o, *n);
            }
            let old_res = out.instr(s).result;
            let nid = out.create_instr(op);
            out.blocks[src.index()].instrs.push(nid);
            if let (Some(o), Some(n)) = (old_res, out.instr(nid).result) {
                remap.insert(o, n);
            }
        }
        let mut op = out.instr(iid).op.clone();
        for (o, n) in &remap {
            op.replace_use(*o, *n);
        }
        let nid = out.create_instr(op);
        out.blocks[src.index()].instrs.push(nid);
        // replace uses of the original op's result (loads) with the clone
        if let (Some(o), Some(n)) = (out.instr(iid).result, out.instr(nid).result) {
            out.replace_all_uses(o, n);
        }
        super::detach_instr(&mut out, iid);
    }

    // the guards may now be dead — cleanup
    super::dce::run(&mut out);
    super::simplify_cfg::run(&mut out);
    (out, skipped)
}

/// Like `hoist::clone_slice_plan` but with loads permitted in the slice
/// (ORACLE semantics) and multiple roots.
fn clone_slice_with_loads(
    f: &Function,
    roots: &[ValueId],
    src: crate::ir::BlockId,
    dom: &DomTree,
) -> Option<Vec<InstrId>> {
    let instr_blocks = super::instr_blocks(f);
    let available = |v: ValueId| -> bool {
        match f.value(v).def {
            ValueDef::Param(_) => true,
            ValueDef::Instr(iid) => match instr_blocks[iid.index()] {
                Some(bb) => bb == src || dom.strictly_dominates(bb, src),
                None => false,
            },
        }
    };
    let mut order: Vec<InstrId> = Vec::new();
    let mut seen: HashSet<InstrId> = HashSet::new();

    fn visit(
        f: &Function,
        v: ValueId,
        available: &dyn Fn(ValueId) -> bool,
        seen: &mut HashSet<InstrId>,
        order: &mut Vec<InstrId>,
    ) -> bool {
        if available(v) {
            return true;
        }
        let ValueDef::Instr(iid) = f.value(v).def else { return false };
        if seen.contains(&iid) {
            return true;
        }
        let op = &f.instr(iid).op;
        let ok = !matches!(op, Op::Phi { .. } | Op::Store { .. })
            && !matches!(
                op,
                Op::SendLdAddr { .. }
                    | Op::SendStAddr { .. }
                    | Op::ConsumeVal { .. }
                    | Op::ProduceVal { .. }
                    | Op::PoisonVal { .. }
            );
        if !ok {
            return false;
        }
        seen.insert(iid);
        for u in op.uses() {
            if !visit(f, u, available, seen, order) {
                return false;
            }
        }
        order.push(iid);
        true
    }

    for &r in roots {
        if !visit(f, r, &available, &mut seen, &mut order) {
            return None;
        }
    }
    Some(order)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::LodAnalysis;
    use crate::ir::parser::parse_single;

    #[test]
    fn oracle_removes_lod() {
        let (m, f) = parse_single(
            r#"
array @A : i64[100]
array @idx : i64[100]

func @fig1c(%n: i64) {
entry:
  %c0 = const.i 0
  br header
header:
  %i = phi i64 [entry: %c0], [latch: %inext]
  %cc = icmp.lt %i, %n
  condbr %cc, body, exit
body:
  %a = load @A[%i]
  %zero = const.i 0
  %p = icmp.gt %a, %zero
  condbr %p, then, latch
then:
  %w = load @idx[%i]
  %aw = load @A[%w]
  %c1 = const.i 1
  %fv = add.i %aw, %c1
  store @A[%w], %fv
  br latch
latch:
  %c1b = const.i 1
  %inext = add.i %i, %c1b
  br header
exit:
  ret
}
"#,
        )
        .unwrap();
        let (oracle, skipped) = flatten_lod(&m, &f);
        assert_eq!(skipped, 0);
        crate::ir::verify::verify_function(&m, &oracle).unwrap();
        let lod2 = LodAnalysis::new(&m, &oracle);
        assert!(
            lod2.control_lod.is_empty(),
            "oracle must have no control LoD left: {:?}",
            lod2.control_lod
        );
    }
}
