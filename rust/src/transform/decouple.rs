//! The DAE decoupling transformation — paper §3.2.
//!
//! The original function is cloned twice:
//!
//! - **AGU slice**: every `load` becomes `send_ld_addr` (+ a
//!   `consume_val` on the DU→AGU value stream when the AGU itself needs
//!   the loaded value — the synchronised case of Fig. 1b); every `store`
//!   becomes `send_st_addr`. Dead code (compute, store values) is then
//!   eliminated.
//! - **CU slice**: every `load` becomes `consume_val` on the DU→CU value
//!   stream; every `store` becomes `produce_val`. Address computation
//!   dies.
//!
//! Streams are **per array**: all static ops on one array share a request
//! stream and a value stream; the `mem` tag identifies the static op so
//! the DU can route values only to units that still consume them after
//! DCE (`agu_consumes` / `cu_consumes`).

use super::dce;
use crate::ir::{ArrayId, BlockId, ChanKind, Function, InstrId, Module, Op};

/// Metadata for one static memory operation of the original program.
#[derive(Clone, Debug)]
pub struct MemOpInfo {
    pub mem: u32,
    pub is_store: bool,
    pub arr: ArrayId,
    /// Block in the *original* CFG (== AGU/CU block ids at decoupling
    /// time).
    pub home: BlockId,
}

/// A decoupled program: AGU + CU slices over shared channels, plus the
/// static memory-op table.
#[derive(Clone, Debug)]
pub struct DaeProgram {
    pub module: Module,
    /// Index of the AGU function in `module.funcs`.
    pub agu: usize,
    /// Index of the CU function in `module.funcs`.
    pub cu: usize,
    pub mem_ops: Vec<MemOpInfo>,
    /// Static ops whose loaded value the AGU consumes (post-DCE).
    pub agu_consumes: Vec<u32>,
    /// Static ops whose loaded value the CU consumes (post-DCE).
    pub cu_consumes: Vec<u32>,
}

impl DaeProgram {
    pub fn agu_fn(&self) -> &Function {
        &self.module.funcs[self.agu]
    }

    pub fn cu_fn(&self) -> &Function {
        &self.module.funcs[self.cu]
    }
}

/// Decouple `f` (a function of `m`) into AGU + CU slices.
///
/// `run_dce`: run the §3.2 step-3 cleanup (always true in production;
/// tests disable it to inspect raw slices).
pub fn decouple(m: &Module, f: &Function, run_dce: bool) -> DaeProgram {
    let mut module = Module { arrays: m.arrays.clone(), chans: m.chans.clone(), funcs: vec![] };

    // Enumerate static memory ops in layout order.
    let mut mem_ops: Vec<MemOpInfo> = Vec::new();
    let mut mem_of_instr: Vec<Option<u32>> = vec![None; f.instrs.len()];
    for (bi, b) in f.blocks.iter().enumerate() {
        for &iid in &b.instrs {
            match f.instr(iid).op {
                Op::Load { arr, .. } => {
                    let mem = mem_ops.len() as u32;
                    mem_of_instr[iid.index()] = Some(mem);
                    mem_ops.push(MemOpInfo {
                        mem,
                        is_store: false,
                        arr,
                        home: BlockId(bi as u32),
                    });
                }
                Op::Store { arr, .. } => {
                    let mem = mem_ops.len() as u32;
                    mem_of_instr[iid.index()] = Some(mem);
                    mem_ops.push(MemOpInfo {
                        mem,
                        is_store: true,
                        arr,
                        home: BlockId(bi as u32),
                    });
                }
                _ => {}
            }
        }
    }

    // ---- AGU slice --------------------------------------------------------
    let mut agu = f.clone();
    agu.name = format!("{}__agu", f.name);
    for (bi, _) in f.blocks.iter().enumerate() {
        // iterate over a snapshot: we insert into agu blocks as we go
        let instrs_snapshot = agu.blocks[bi].instrs.clone();
        for &iid in &instrs_snapshot {
            let Some(mem) = mem_of_instr[iid.index()] else { continue };
            match agu.instr(iid).op.clone() {
                Op::Load { arr, idx, ty } => {
                    let addr_ch = module.add_chan(ChanKind::LdAddr, arr);
                    let val_ch = module.add_chan(ChanKind::LdValAgu, arr);
                    let old_result = agu.instr(iid).result;
                    // load -> send_ld_addr
                    agu.instr_mut(iid).op = Op::SendLdAddr { chan: addr_ch, mem, idx };
                    agu.instr_mut(iid).result = None;
                    // followed by consume_val on the AGU value stream
                    let cons = agu.create_instr(Op::ConsumeVal { chan: val_ch, mem, ty });
                    let pos = agu.blocks[bi].instrs.iter().position(|&i| i == iid).unwrap();
                    agu.blocks[bi].instrs.insert(pos + 1, cons);
                    if let (Some(old), Some(new)) = (old_result, agu.instr(cons).result) {
                        agu.replace_all_uses(old, new);
                    }
                }
                Op::Store { arr, idx, .. } => {
                    let addr_ch = module.add_chan(ChanKind::StAddr, arr);
                    agu.instr_mut(iid).op = Op::SendStAddr { chan: addr_ch, mem, idx };
                    agu.instr_mut(iid).result = None;
                }
                _ => {}
            }
        }
    }

    // ---- CU slice ---------------------------------------------------------
    let mut cu = f.clone();
    cu.name = format!("{}__cu", f.name);
    for iid_raw in 0..cu.instrs.len() {
        let iid = InstrId(iid_raw as u32);
        let Some(mem) = mem_of_instr.get(iid_raw).copied().flatten() else { continue };
        match cu.instr(iid).op.clone() {
            Op::Load { arr, ty, .. } => {
                let val_ch = module.add_chan(ChanKind::LdVal, arr);
                cu.instr_mut(iid).op = Op::ConsumeVal { chan: val_ch, mem, ty };
                // result value id unchanged: uses keep working
            }
            Op::Store { arr, val, .. } => {
                let st_ch = module.add_chan(ChanKind::StVal, arr);
                cu.instr_mut(iid).op = Op::ProduceVal { chan: st_ch, mem, val };
            }
            _ => {}
        }
    }

    if run_dce {
        dce::run(&mut agu);
        dce::run(&mut cu);
    }

    let collect_consumes = |f: &Function| -> Vec<u32> {
        let mut v = Vec::new();
        for b in &f.blocks {
            for &iid in &b.instrs {
                if let Op::ConsumeVal { mem, .. } = f.instr(iid).op {
                    v.push(mem);
                }
            }
        }
        v.sort();
        v.dedup();
        v
    };
    let agu_consumes = collect_consumes(&agu);
    let cu_consumes = collect_consumes(&cu);

    module.funcs.push(agu);
    module.funcs.push(cu);
    DaeProgram { module, agu: 0, cu: 1, mem_ops, agu_consumes, cu_consumes }
}

/// Recompute the consume sets after later passes (hoisting + DCE can drop
/// AGU consumes — the whole point of speculation).
pub fn refresh_consumes(p: &mut DaeProgram) {
    let collect = |f: &Function| -> Vec<u32> {
        let mut v = Vec::new();
        for b in &f.blocks {
            for &iid in &b.instrs {
                if let Op::ConsumeVal { mem, .. } = f.instr(iid).op {
                    v.push(mem);
                }
            }
        }
        v.sort();
        v.dedup();
        v
    };
    p.agu_consumes = collect(&p.module.funcs[p.agu]);
    p.cu_consumes = collect(&p.module.funcs[p.cu]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_single;

    const FIG1B: &str = r#"
array @A : i64[100]
array @idx : i64[100]

func @fig1b(%n: i64) {
entry:
  %c0 = const.i 0
  br header
header:
  %i = phi i64 [entry: %c0], [latch: %inext]
  %cc = icmp.lt %i, %n
  condbr %cc, body, exit
body:
  %a = load @A[%i]
  %zero = const.i 0
  %p = icmp.gt %a, %zero
  condbr %p, then, latch
then:
  %w = load @idx[%i]
  %aw = load @A[%w]
  %c1 = const.i 1
  %f = add.i %aw, %c1
  store @A[%w], %f
  br latch
latch:
  %c1b = const.i 1
  %inext = add.i %i, %c1b
  br header
exit:
  ret
}
"#;

    #[test]
    fn decouples_fig1b() {
        let (m, f) = parse_single(FIG1B).unwrap();
        let p = decouple(&m, &f, true);
        assert_eq!(p.mem_ops.len(), 4); // 3 loads + 1 store
        // AGU consumes: A[i] (guard, controls the store send) and idx[i]
        // (feeds the store address). A[w]'s value is compute-only → not
        // consumed by the AGU.
        assert_eq!(p.agu_consumes, vec![0, 1], "AGU consumes guard + idx");
        // CU consumes: A[i] (guard for its own branch) and A[w] (compute).
        // idx[i]'s value is address-only → dead in the CU.
        assert_eq!(p.cu_consumes, vec![0, 2]);
        // verify both slices
        crate::ir::verify::verify_module(&p.module).unwrap();
        // AGU has no loads/stores left
        for f in &p.module.funcs {
            for b in &f.blocks {
                for &iid in &b.instrs {
                    assert!(!f.instr(iid).op.is_memory());
                }
            }
        }
    }

    #[test]
    fn streams_are_per_array() {
        let (m, f) = parse_single(FIG1B).unwrap();
        let p = decouple(&m, &f, true);
        // A: ld_addr, ld_val_agu, ld_val, st_addr, st_val → 5 chans;
        // idx: ld_addr, ld_val_agu (created optimistically) → 2 chans.
        let a_chans =
            p.module.chans.iter().filter(|c| p.module.array(c.arr).name == "A").count();
        assert_eq!(a_chans, 5);
    }
}
