//! Algorithms 2 + 3 — poisoning mis-speculated stores in the CU (§5.2).
//!
//! ## Paper formulation vs. this implementation
//!
//! Algorithm 2 enumerates *every path* from each spec block to the loop
//! latch, scanning a pending list of speculated requests per path and
//! deduplicating poison insertions per `(edge, request)`. We implement an
//! equivalent **edge-local** form built on an invariant the paper's proof
//! implies but never states: with the scan rules
//!
//! - pop the front request when the edge destination is its `trueBB`
//!   (used; stop scanning this edge — paper line 13),
//! - pop-and-poison the front request while its `trueBB` is unreachable
//!   from the destination (paper line 14-17),
//! - otherwise stop (the prose rule: an unreachable later request must
//!   wait for an earlier still-usable one),
//!
//! the pending list *after* scanning every edge into a block `s` is the
//! same on all paths, because (a) all paths start with the same list at
//! `specBB`, (b) "used at `s`" and "dead at `s`" are path-independent
//! facts of the forward DAG, and (c) within-DAG acyclicity means a
//! visited `trueBB` can never be forward-reachable again. We therefore
//! propagate one pending list per block in topological order — O(E·R)
//! instead of exponential — and **assert** list agreement at joins, which
//! dynamically re-checks the invariant on every compile. A literal
//! all-paths implementation ([`poison_plan_naive`]) is kept for
//! cross-validation in tests.
//!
//! Algorithm 3 placement cases map as follows:
//! - case 1/2 (conflict or no dominance) → a poison block on the edge
//!   ([`Place::OnEdge`]), with a steering *predicate* instead of steering
//!   branches when `specBB` does not dominate the edge source (the paper
//!   itself notes the equivalence with predication in §9);
//! - case 3 → poison prepended to the destination block, after φs
//!   ([`Place::Prologue`]).
//!
//! Iteration-final edges (the loop backedge, or a loop/function exit)
//! poison every remaining pending request — this covers LoD loop *exit*
//! conditions (`while (A[i] ...)`), where the AGU over-runs by design.

use super::decouple::DaeProgram;
use super::hoist::{spec_region, SpecReq, SpecReqMap};
use crate::analysis::{DomTree, LoopInfo, Reachability};
use crate::ir::{BlockId, ChanKind, Function, Op, Type, ValueId};
use anyhow::{bail, Result};
use std::collections::HashMap;

#[derive(Clone, Debug, Default)]
pub struct PoisonStats {
    /// New blocks created on edges (paper Table 1 "Poison Blocks",
    /// pre-merge; `merge_poison` reduces this).
    pub poison_blocks: usize,
    /// Static poison calls inserted (paper Table 1 "Poison Calls").
    pub poison_calls: usize,
}

#[derive(Clone, Debug, PartialEq, Eq, Hash)]
enum Place {
    /// Poison at the top of `block` (after φs), order given by `seq`.
    Prologue { block: BlockId },
    /// Poison in a (shared) block created on the `from -> to` edge.
    OnEdge { from: BlockId, to: BlockId },
}

#[derive(Clone, Debug)]
struct PlannedPoison {
    mem: u32,
    arr: crate::ir::ArrayId,
    place: Place,
    /// Guard with the specBB steering flag (Algorithm 3 case 2).
    guard: Option<BlockId>, // specBB whose flag guards this poison
    seq: usize,
}

/// Run Algorithms 2 + 3 on the CU slice of `p` given the hoist map.
pub fn place_poisons(p: &mut DaeProgram, map: &SpecReqMap) -> Result<PoisonStats> {
    let cu_idx = p.cu;

    // Analyses on the *pre-modification* CU (same structure as the AGU at
    // hoist time).
    let (plan, needs_flag) = {
        let cu = &p.module.funcs[cu_idx];
        let dom = DomTree::new(cu);
        let loops = LoopInfo::new(cu, &dom);
        let reach = Reachability::new(cu, &dom);
        compute_plan(cu, map, &dom, &loops, &reach)?
    };

    // Build steering-flag networks for spec blocks that need them.
    let mut flags: HashMap<BlockId, Vec<Option<ValueId>>> = HashMap::new();
    {
        let dom = DomTree::new(&p.module.funcs[cu_idx]);
        let loops = LoopInfo::new(&p.module.funcs[cu_idx], &dom);
        for &spec_bb in &needs_flag {
            let net = build_flag_network(&mut p.module.funcs[cu_idx], spec_bb, &loops);
            flags.insert(spec_bb, net);
        }
    }

    // Apply: group OnEdge placements per edge, split each edge once.
    let mut stats = PoisonStats::default();
    let mut edge_blocks: HashMap<(BlockId, BlockId), BlockId> = HashMap::new();
    let mut sorted = plan;
    sorted.sort_by_key(|pp| pp.seq);

    let cu = &mut p.module.funcs[cu_idx];
    // Prologue insert positions per block: after φs; track how many
    // prologue poisons were already inserted to preserve seq order.
    let mut prologue_counts: HashMap<BlockId, usize> = HashMap::new();

    for pp in &sorted {
        let chan = p.module.chans
            .iter()
            .position(|c| c.kind == ChanKind::StVal && c.arr == pp.arr)
            .map(|i| crate::ir::ChanId(i as u32))
            .expect("st_val channel exists for speculated store");
        let pred = pp.guard.map(|spec_bb| {
            let place_block = match pp.place {
                Place::Prologue { block } => block,
                Place::OnEdge { from, .. } => from,
            };
            flags[&spec_bb][place_block.index()]
                .expect("flag defined for region block")
        });
        let op = Op::PoisonVal { chan, mem: pp.mem, pred };
        match pp.place {
            Place::Prologue { block } => {
                let iid = cu.create_instr(op);
                let insts = &mut cu.blocks[block.index()].instrs;
                let mut pos = 0;
                while pos < insts.len()
                    && matches!(cu.instrs[insts[pos].index()].op, Op::Phi { .. })
                {
                    pos += 1;
                }
                let off = prologue_counts.entry(block).or_insert(0);
                insts.insert(pos + *off, iid);
                *off += 1;
            }
            Place::OnEdge { from, to } => {
                let pb = *edge_blocks.entry((from, to)).or_insert_with(|| {
                    stats.poison_blocks += 1;
                    cu.split_edge(from, to, &format!("poison_{}_{}", from.0, to.0))
                });
                let iid = cu.create_instr(op);
                cu.blocks[pb.index()].instrs.push(iid);
            }
        }
        stats.poison_calls += 1;
    }

    Ok(stats)
}

/// Edge-local Algorithm 2: compute all planned poisons. Returns the plan
/// plus the set of spec blocks whose steering flag is needed.
fn compute_plan(
    cu: &Function,
    map: &SpecReqMap,
    dom: &DomTree,
    loops: &LoopInfo,
    reach: &Reachability,
) -> Result<(Vec<PlannedPoison>, Vec<BlockId>)> {
    let mut plan: Vec<PlannedPoison> = Vec::new();
    let mut needs_flag: Vec<BlockId> = Vec::new();
    let mut seq = 0usize;

    for (spec_bb, reqs) in map {
        let spec_bb = *spec_bb;
        // Group requests by trueBB preserving order (paper: trueBlocks is
        // an insertion-ordered set; same-block requests resolve together).
        let mut tbs: Vec<(BlockId, Vec<&SpecReq>)> = Vec::new();
        for r in reqs {
            if !r.is_store {
                continue; // speculative loads are handled by §5.4, not poisoned
            }
            match tbs.last_mut() {
                Some((bb, list)) if *bb == r.true_bb => list.push(r),
                _ => tbs.push((r.true_bb, vec![r])),
            }
        }
        if tbs.is_empty() {
            continue;
        }
        // sanity: a trueBB appearing twice non-adjacently would break the
        // set semantics
        for i in 0..tbs.len() {
            for j in i + 1..tbs.len() {
                if tbs[i].0 == tbs[j].0 {
                    bail!("trueBB {} appears non-adjacently in spec list", tbs[i].0);
                }
            }
        }

        let (region, enters_inner) = spec_region(cu, spec_bb, dom, loops);
        if enters_inner {
            bail!("spec region of {spec_bb} enters an inner loop (hoist should have skipped it)");
        }
        let own_loop = loops.innermost_idx(spec_bb);
        let in_region = {
            let mut v = vec![false; cu.num_blocks()];
            for &b in &region {
                v[b.index()] = true;
            }
            v
        };

        // pending list (tb indices) per region block
        let mut pending_at: HashMap<BlockId, Vec<usize>> = HashMap::new();
        pending_at.insert(spec_bb, (0..tbs.len()).collect());

        for &pblk in &region {
            let Some(pending) = pending_at.get(&pblk).cloned() else {
                // not reachable from spec_bb inside region (can happen for
                // region entry = spec_bb only); skip
                continue;
            };
            for s in cu.succs(pblk) {
                // classify the edge
                let is_backedge = dom.dominates(s, pblk);
                let leaves_loop = match own_loop {
                    Some(li) => !loops.loops[li].contains(s),
                    None => false,
                };
                let is_final = is_backedge || leaves_loop || cu.succs(pblk).is_empty();
                let mut out = pending.clone();

                if is_final || !in_region[s.index()] {
                    // iteration over: poison everything still pending
                    for &ti in &out {
                        emit(
                            &mut plan,
                            &mut needs_flag,
                            &mut seq,
                            cu,
                            dom,
                            reach,
                            spec_bb,
                            &tbs[ti],
                            pblk,
                            s,
                            /*final_edge=*/ true,
                        );
                    }
                    continue;
                }

                // normal scan
                while let Some(&front) = out.first() {
                    let (tb, _) = &tbs[front];
                    if *tb == s {
                        out.remove(0); // used at s; stop (paper line 13)
                        break;
                    } else if !reach.reachable(s, *tb) {
                        emit(
                            &mut plan,
                            &mut needs_flag,
                            &mut seq,
                            cu,
                            dom,
                            reach,
                            spec_bb,
                            &tbs[front],
                            pblk,
                            s,
                            false,
                        );
                        out.remove(0);
                    } else {
                        break; // earlier request still usable: wait
                    }
                }

                // join coherence: the Lemma 6.1 invariant
                match pending_at.get(&s) {
                    Some(prev) => {
                        if prev != &out {
                            bail!(
                                "pending-list mismatch at {} from {}: {:?} vs {:?} \
                                 (speculative order cannot be matched — Lemma 6.1 violated)",
                                s, pblk, prev, out
                            );
                        }
                    }
                    None => {
                        pending_at.insert(s, out);
                    }
                }
            }
        }
    }

    // dedupe: a given request is poisoned at most once per placement
    let mut seen: HashMap<(u32, Place), usize> = HashMap::new();
    let mut deduped: Vec<PlannedPoison> = Vec::new();
    for pp in plan {
        let key = (pp.mem, pp.place.clone());
        if seen.contains_key(&key) {
            continue;
        }
        seen.insert(key, pp.seq);
        deduped.push(pp);
    }
    needs_flag.sort();
    needs_flag.dedup();
    Ok((deduped, needs_flag))
}

#[allow(clippy::too_many_arguments)]
fn emit(
    plan: &mut Vec<PlannedPoison>,
    needs_flag: &mut Vec<BlockId>,
    seq: &mut usize,
    _cu: &Function,
    dom: &DomTree,
    reach: &Reachability,
    spec_bb: BlockId,
    (tb, reqs): &(BlockId, Vec<&SpecReq>),
    from: BlockId,
    to: BlockId,
    final_edge: bool,
) {
    for r in reqs {
        // Algorithm 3 case analysis:
        // case 1: trueBB can still reach the destination → prologue would
        //   fire on paths where the store is real ⇒ edge block.
        // case 2: specBB does not dominate the destination ⇒ edge block
        //   (+ steering guard when the edge source itself is not
        //   dominated).
        // case 3: otherwise prepend to the destination block.
        let conflict = !final_edge && reach.reachable(*tb, to);
        let place = if conflict || !dom.dominates(spec_bb, to) || final_edge {
            Place::OnEdge { from, to }
        } else {
            Place::Prologue { block: to }
        };
        let guard_needed = match place {
            Place::OnEdge { from, .. } => !dom.dominates(spec_bb, from),
            Place::Prologue { .. } => false, // case 3 requires dominance
        };
        let guard = if guard_needed {
            if !needs_flag.contains(&spec_bb) {
                needs_flag.push(spec_bb);
            }
            Some(spec_bb)
        } else {
            None
        };
        plan.push(PlannedPoison { mem: r.mem, arr: r.arr, place: place.clone(), guard, seq: *seq });
        *seq += 1;
    }
}

/// Build the per-block steering flag ("did this iteration pass through
/// `spec_bb`?") as an SSA φ network over `spec_bb`'s innermost loop (or
/// the whole function when it is not in a loop). Returns the flag value
/// valid at the *end* of each block.
fn build_flag_network(
    f: &mut Function,
    spec_bb: BlockId,
    loops: &LoopInfo,
) -> Vec<Option<ValueId>> {
    let scope: Vec<BlockId> = match loops.innermost(spec_bb) {
        Some(l) => l.blocks.clone(),
        None => (0..f.num_blocks() as u32).map(BlockId).collect(),
    };
    let header = loops.innermost(spec_bb).map(|l| l.header).unwrap_or(f.entry);
    let in_scope = {
        let mut v = vec![false; f.num_blocks()];
        for &b in &scope {
            v[b.index()] = true;
        }
        v
    };
    let preds = f.preds();

    // RPO over scope from header.
    let dom = DomTree::new(f);
    let order = crate::analysis::rpo::reverse_post_order_from(f, header, &|a, b| {
        dom.dominates(b, a) || !in_scope[b.index()]
    });

    let mut flag: Vec<Option<ValueId>> = vec![None; f.num_blocks()];

    // const false in header (after φs), const true in spec_bb.
    let insert_after_phis = |f: &mut Function, bb: BlockId, op: Op| -> ValueId {
        let iid = f.create_instr(op);
        let res = f.instr(iid).result.unwrap();
        let insts = &mut f.blocks[bb.index()].instrs;
        let mut pos = 0;
        while pos < insts.len() && matches!(f.instrs[insts[pos].index()].op, Op::Phi { .. }) {
            pos += 1;
        }
        insts.insert(pos, iid);
        res
    };

    let false_v = insert_after_phis(f, header, Op::ConstB(false));
    flag[header.index()] = Some(false_v);

    // first pass: create φs where needed (multi-pred in-scope blocks)
    let mut phi_of: HashMap<BlockId, ValueId> = HashMap::new();
    for &b in &order {
        if b == header {
            continue;
        }
        let scope_preds: Vec<BlockId> = preds[b.index()]
            .iter()
            .copied()
            .filter(|p| in_scope[p.index()])
            .collect();
        if b == spec_bb {
            let t = insert_after_phis(f, b, Op::ConstB(true));
            flag[b.index()] = Some(t);
            continue;
        }
        if scope_preds.len() == 1 {
            // inherit (filled in pass 2, pred processed earlier in RPO —
            // except backedge preds, which cannot target non-headers in a
            // reducible CFG)
            flag[b.index()] = flag[scope_preds[0].index()];
            if flag[b.index()].is_none() {
                // pred not yet known (shouldn't happen in RPO) — create φ
                let phi = insert_after_phis(
                    f,
                    b,
                    Op::Phi { ty: Type::B1, incomings: vec![] },
                );
                phi_of.insert(b, phi);
                flag[b.index()] = Some(phi);
            }
        } else {
            let phi = insert_after_phis(f, b, Op::Phi { ty: Type::B1, incomings: vec![] });
            phi_of.insert(b, phi);
            flag[b.index()] = Some(phi);
        }
    }

    // second pass: fill φ incomings (all preds now have flags; inner-loop
    // headers take their backedge value from themselves via the latch
    // flag, which equals the header flag since spec_bb is outside inner
    // loops).
    for (b, phi) in phi_of {
        let incomings: Vec<(BlockId, ValueId)> = preds[b.index()]
            .iter()
            .filter(|p| in_scope[p.index()])
            .map(|&p| (p, flag[p.index()].expect("pred flag known")))
            .collect();
        if let crate::ir::ValueDef::Instr(iid) = f.value(phi).def {
            if let Op::Phi { incomings: inc, .. } = &mut f.instr_mut(iid).op {
                *inc = incomings;
            }
        }
    }

    flag
}

/// Test hook: run the edge-local planner and return `(edge_to, mem)`
/// placements in a naive-comparable form (prologue placements report the
/// destination block; edge placements report the edge destination).
pub fn plan_placements_for_tests(
    cu: &Function,
    map: &SpecReqMap,
) -> Result<std::collections::BTreeSet<(u32, u32)>> {
    let dom = DomTree::new(cu);
    let loops = LoopInfo::new(cu, &dom);
    let reach = Reachability::new(cu, &dom);
    let (plan, _) = compute_plan(cu, map, &dom, &loops, &reach)?;
    Ok(plan
        .into_iter()
        .map(|pp| {
            let dst = match pp.place {
                Place::Prologue { block } => block.0,
                Place::OnEdge { to, .. } => to.0,
            };
            (dst, pp.mem)
        })
        .collect())
}

/// Paper-literal Algorithm 2 (all-paths enumeration) returning the set of
/// `(edge, mem)` poisons. Exponential; used only by tests to cross-check
/// [`compute_plan`]. Panics if the region has more than `max_paths`
/// paths.
pub fn poison_plan_naive(
    cu: &Function,
    map: &SpecReqMap,
    max_paths: usize,
) -> Result<std::collections::BTreeSet<(u32, u32, u32)>> {
    let dom = DomTree::new(cu);
    let loops = LoopInfo::new(cu, &dom);
    let reach = Reachability::new(cu, &dom);
    let mut out: std::collections::BTreeSet<(u32, u32, u32)> = Default::default();

    for (spec_bb, reqs) in map {
        let spec_bb = *spec_bb;
        let mut tbs: Vec<(BlockId, Vec<&SpecReq>)> = Vec::new();
        for r in reqs {
            if !r.is_store {
                continue;
            }
            match tbs.last_mut() {
                Some((bb, list)) if *bb == r.true_bb => list.push(r),
                _ => tbs.push((r.true_bb, vec![r])),
            }
        }
        if tbs.is_empty() {
            continue;
        }
        let own_loop = loops.innermost_idx(spec_bb);

        // DFS over all paths.
        let mut stack: Vec<(BlockId, Vec<usize>)> = vec![(spec_bb, (0..tbs.len()).collect())];
        let mut paths = 0usize;
        while let Some((b, pending)) = stack.pop() {
            let succs = cu.succs(b);
            if succs.is_empty() {
                paths += 1;
                if paths > max_paths {
                    bail!("too many paths");
                }
                continue;
            }
            for s in succs {
                let is_backedge = dom.dominates(s, b);
                let leaves = match own_loop {
                    Some(li) => !loops.loops[li].contains(s),
                    None => false,
                };
                let mut p2 = pending.clone();
                if is_backedge || leaves {
                    for &ti in &p2 {
                        for r in &tbs[ti].1 {
                            out.insert((b.0, s.0, r.mem));
                        }
                    }
                    paths += 1;
                    if paths > max_paths {
                        bail!("too many paths");
                    }
                    continue;
                }
                while let Some(&front) = p2.first() {
                    if tbs[front].0 == s {
                        p2.remove(0);
                        break;
                    } else if !reach.reachable(s, tbs[front].0) {
                        for r in &tbs[front].1 {
                            out.insert((b.0, s.0, r.mem));
                        }
                        p2.remove(0);
                    } else {
                        break;
                    }
                }
                stack.push((s, p2));
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::LodAnalysis;
    use crate::ir::parser::parse_single;
    use crate::transform::decouple::decouple;
    use crate::transform::hoist::hoist_speculative_requests;

    fn spec_compile(src: &str) -> (DaeProgram, SpecReqMap, PoisonStats) {
        let (m, f) = parse_single(src).unwrap();
        let lod = LodAnalysis::new(&m, &f);
        let dom = DomTree::new(&f);
        let loops = LoopInfo::new(&f, &dom);
        let reach = Reachability::new(&f, &dom);
        let mut p = decouple(&m, &f, false);
        let hr = hoist_speculative_requests(&mut p, &lod, &dom, &loops, &reach);
        assert!(hr.refused.is_empty(), "{:?}", hr.refused);
        let stats = place_poisons(&mut p, &hr.map).unwrap();
        (p, hr.map, stats)
    }

    #[test]
    fn fig1c_single_poison() {
        // Figure 1c: one guarded store → one poison call on the skip path.
        let (p, map, stats) = spec_compile(
            r#"
array @A : i64[100]
array @idx : i64[100]

func @fig1c(%n: i64) {
entry:
  %c0 = const.i 0
  br header
header:
  %i = phi i64 [entry: %c0], [latch: %inext]
  %cc = icmp.lt %i, %n
  condbr %cc, body, exit
body:
  %a = load @A[%i]
  %zero = const.i 0
  %p = icmp.gt %a, %zero
  condbr %p, then, latch
then:
  %w = load @idx[%i]
  %aw = load @A[%w]
  %c1 = const.i 1
  %fv = add.i %aw, %c1
  store @A[%w], %fv
  br latch
latch:
  %c1b = const.i 1
  %inext = add.i %i, %c1b
  br header
exit:
  ret
}
"#,
        );
        assert_eq!(map.len(), 1);
        // store + the A[w] load + idx load are hoisted (all in `then`,
        // region of `body`)
        assert_eq!(stats.poison_calls, 1, "one poison for the skip path");
        // poison lands in `latch` (case 3: body dominates latch, store
        // can't reach latch... A store's trueBB `then` → edge body→latch:
        // reach(then, latch) = true (then→latch) ⇒ case 1 edge block OR
        // prologue — either way exactly one call.
        crate::ir::verify::verify_module(&p.module).unwrap();
    }

    #[test]
    fn fig3_order_and_placement() {
        let (p, map, stats) = spec_compile(crate::transform::hoist::tests::FIG3);
        // three stores speculated at `body`
        assert_eq!(map.len(), 1);
        assert_eq!(map[0].1.len(), 3);
        // every path poisons exactly the stores it does not execute:
        // 2 poisons per path × 3 paths, deduped across placements
        assert!(stats.poison_calls >= 2, "calls={}", stats.poison_calls);
        crate::ir::verify::verify_module(&p.module).unwrap();
    }

    #[test]
    fn naive_and_fast_agree_on_fig3() {
        let (m, f) = parse_single(crate::transform::hoist::tests::FIG3).unwrap();
        let lod = LodAnalysis::new(&m, &f);
        let dom = DomTree::new(&f);
        let loops = LoopInfo::new(&f, &dom);
        let reach = Reachability::new(&f, &dom);
        let mut p = decouple(&m, &f, false);
        let hr = hoist_speculative_requests(&mut p, &lod, &dom, &loops, &reach);

        // compute fast plan placements as (edge, mem) via the naive-
        // comparable subset: rerun compute_plan on the pristine CU.
        let cu = &p.module.funcs[p.cu];
        let domc = DomTree::new(cu);
        let loopsc = LoopInfo::new(cu, &domc);
        let reachc = Reachability::new(cu, &domc);
        let (plan, _) = compute_plan(cu, &hr.map, &domc, &loopsc, &reachc).unwrap();
        let naive = poison_plan_naive(cu, &hr.map, 10_000).unwrap();

        // naive yields (from,to,mem); fast yields Prologue/OnEdge — map
        // fast placements to edges for comparison: Prologue{b} matches any
        // naive edge (*, b, mem); OnEdge matches exactly.
        for (from, to, mem) in &naive {
            let hit = plan.iter().any(|pp| {
                pp.mem == *mem
                    && match &pp.place {
                        Place::OnEdge { from: f2, to: t2 } => {
                            f2.0 == *from && t2.0 == *to
                        }
                        Place::Prologue { block } => block.0 == *to,
                    }
            });
            assert!(hit, "naive poison ({from},{to},m{mem}) missing from fast plan");
        }
        // and the fast plan has no extra mems per edge-dst beyond naive
        for pp in &plan {
            let dst = match &pp.place {
                Place::OnEdge { to, .. } => to.0,
                Place::Prologue { block } => block.0,
            };
            assert!(
                naive.iter().any(|(_, t, m2)| *t == dst && *m2 == pp.mem),
                "fast plan has extra poison {:?}",
                pp
            );
        }
    }
}
