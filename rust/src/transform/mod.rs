//! The paper's compiler transformations.
//!
//! - [`decouple`] — §3.2: split the original function into AGU and CU
//!   slices communicating over per-array FIFO streams.
//! - [`hoist`] — Algorithm 1: speculative hoisting of memory requests in
//!   the AGU to LoD chain-head source blocks, in reverse post-order.
//! - [`poison`] — Algorithms 2 + 3: placing poison (store-invalidate)
//!   calls in the CU such that on every path the value/poison order
//!   matches the AGU's speculative request order (Lemma 6.1).
//! - [`merge_poison`] — §5.3: merging equivalent poison blocks.
//! - [`spec_load`] — §5.4: speculative load consumption.
//! - [`oracle`] — §8.1.1: manual LoD removal (functionally wrong upper
//!   bound).
//! - [`dce`] / [`simplify_cfg`] — the standard cleanups §3.2 step 3 calls
//!   for.
//! - [`pipeline`] — composes everything into the four evaluated
//!   architectures: STA, DAE, SPEC, ORACLE.

pub mod dce;
pub mod decouple;
pub mod hoist;
pub mod merge_poison;
pub mod oracle;
pub mod pipeline;
pub mod poison;
pub mod simplify_cfg;
pub mod spec_load;

pub use decouple::{decouple, DaeProgram};
pub use hoist::{hoist_speculative_requests, HoistResult, SpecReq, SpecReqMap};
pub use pipeline::{build, Arch, Compiled};
pub use poison::{place_poisons, PoisonStats};

use crate::ir::{BlockId, Function, InstrId};

/// Find the block containing each instruction (id-indexed dense map).
pub(crate) fn instr_blocks(f: &Function) -> Vec<Option<BlockId>> {
    let mut map = vec![None; f.instrs.len()];
    for (bi, b) in f.blocks.iter().enumerate() {
        for &iid in &b.instrs {
            map[iid.index()] = Some(BlockId(bi as u32));
        }
    }
    map
}

/// Remove `iid` from whatever block contains it.
pub(crate) fn detach_instr(f: &mut Function, iid: InstrId) {
    for b in &mut f.blocks {
        if let Some(pos) = b.instrs.iter().position(|&i| i == iid) {
            b.instrs.remove(pos);
            return;
        }
    }
}
