//! Algorithm 1 — control-flow hoisting of AGU requests (paper §5.1).
//!
//! For every LoD chain-head source block `srcBB` (§5.1.2), traverse the
//! CFG region from `srcBB` to the loop latch in **reverse post-order**
//! (the topological order of the forward DAG — §5.1.3) and move every
//! memory request found to the end of `srcBB`, together with a clone of
//! its (pure) address computation.
//!
//! This implementation adds two safety refusals that the paper leaves
//! implicit (its examples satisfy them by construction); both are
//! validated dynamically by the Lemma 6.1 property tests:
//!
//! 1. **Exactly-once coverage** — a request may be hoisted to several
//!    source blocks (paper Fig. 4: `b`, `e` land in both block 2 and 3),
//!    which is only sound if every path to the request's home block
//!    passes through exactly one of them. We check (a) no two target
//!    sources reach one another, and (b) no path reaches the home block
//!    avoiding all targets.
//! 2. **Hoistable addresses** — the request's address slice must be
//!    cloneable at `srcBB` (pure arithmetic over values dominating
//!    `srcBB`; no φ, no consume). Otherwise the request would still
//!    synchronise on DU values, defeating speculation.
//!
//! A refusal poisons speculation for *every* op on the same array
//! (all-or-nothing per stream): partial hoisting would reorder the
//! shared per-array request stream relative to the CU's value stream.

use super::decouple::DaeProgram;
use crate::analysis::{DomTree, LodAnalysis, LoopInfo, Reachability};
use crate::ir::{BlockId, Function, InstrId, Op, ValueDef, ValueId};
use std::collections::{HashMap, HashSet};

/// One speculative request, in AGU issue order at its spec block.
#[derive(Clone, Debug)]
pub struct SpecReq {
    pub mem: u32,
    pub is_store: bool,
    pub arr: crate::ir::ArrayId,
    /// Home block in the (original) CFG — where the request "becomes
    /// true" (the paper's `trueBB`).
    pub true_bb: BlockId,
}

/// Ordered map: spec block → hoisted requests (paper's `SpecReqMap`).
pub type SpecReqMap = Vec<(BlockId, Vec<SpecReq>)>;

#[derive(Clone, Debug, Default)]
pub struct HoistResult {
    pub map: SpecReqMap,
    /// mem ids that could not be speculated (and why).
    pub refused: Vec<(u32, String)>,
}

/// Run Algorithm 1 on the AGU slice of `p`.
///
/// `lod`, `dom`, `loops`, `reach` are computed on the **original**
/// function, whose block structure the AGU clone shares.
pub fn hoist_speculative_requests(
    p: &mut DaeProgram,
    lod: &LodAnalysis,
    dom: &DomTree,
    loops: &LoopInfo,
    reach: &Reachability,
) -> HoistResult {
    let agu_idx = p.agu;
    let mut result = HoistResult::default();

    // ---- collect the hoist plan -------------------------------------------
    // plan: srcBB -> ordered list of send instrs (with home block)
    let mut plan: Vec<(BlockId, Vec<(InstrId, BlockId)>)> = Vec::new();
    {
        let agu = &p.module.funcs[agu_idx];
        for &src in &lod.chain_heads {
            let (region, enters_inner) = spec_region(agu, src, dom, loops);
            if enters_inner {
                // the source's region touches an inner loop: skip this
                // source (requests inside the inner loop belong to their
                // own innermost-loop sources)
                result.refused.push((u32::MAX, format!("source {src} skipped: region enters an inner loop")));
                continue;
            }
            let mut list: Vec<(InstrId, BlockId)> = Vec::new();
            for &bb in &region {
                if bb == src {
                    continue;
                }
                for &iid in &agu.block(bb).instrs {
                    if agu.instr(iid).op.is_send() {
                        list.push((iid, bb));
                    }
                }
            }
            if !list.is_empty() {
                plan.push((src, list));
            }
        }
    }

    // ---- safety refusals ----------------------------------------------------
    // targets per request
    let mut targets: HashMap<InstrId, Vec<BlockId>> = HashMap::new();
    for (src, list) in &plan {
        for (iid, _) in list {
            targets.entry(*iid).or_default().push(*src);
        }
    }
    let mut refused_instrs: HashSet<InstrId> = HashSet::new();
    {
        let agu = &p.module.funcs[agu_idx];
        for (&iid, tgts) in &targets {
            let mem = send_mem(agu, iid);
            // data LoD on this op? (computed on original ids == agu ids)
            if lod.data_lod.contains(&iid) {
                refused_instrs.insert(iid);
                result.refused.push((mem, "data LoD".into()));
                continue;
            }
            // (1a) no two targets reach each other
            let mut bad = false;
            for &a in tgts {
                for &b in tgts {
                    if a != b && reach.reachable(a, b) {
                        bad = true;
                    }
                }
            }
            if bad {
                refused_instrs.insert(iid);
                result.refused.push((mem, "spec sources reach one another".into()));
                continue;
            }
            // (1b) coverage: home unreachable from loop header when all
            // targets are removed
            let home = agu
                .blocks
                .iter()
                .position(|b| b.instrs.contains(&iid))
                .map(|i| BlockId(i as u32))
                .unwrap();
            let start = loops
                .innermost(home)
                .map(|l| l.header)
                .unwrap_or(agu.entry);
            if reachable_avoiding(agu, start, home, tgts, dom) {
                refused_instrs.insert(iid);
                result.refused.push((mem, "home reachable around spec sources".into()));
                continue;
            }
            // loads additionally need a single dominating target so §5.4
            // can re-home the CU consume (see spec_load.rs), and no
            // same-array store may precede them in the hoist plan: the
            // re-homed consume would sit before those stores' produces in
            // the CU while the DU's load RAW-waits on the stores — a
            // genuine cycle (caught by the liveness property tests).
            if matches!(agu.instr(iid).op, Op::SendLdAddr { .. }) {
                let home = agu
                    .blocks
                    .iter()
                    .position(|b| b.instrs.contains(&iid))
                    .map(|i| BlockId(i as u32))
                    .unwrap();
                if tgts.len() != 1 || !dom.dominates(tgts[0], home) {
                    refused_instrs.insert(iid);
                    result.refused.push((mem, "load spec needs one dominating source".into()));
                    continue;
                }
                let my_arr = send_array(&p.module, agu, iid);
                let mut store_before = false;
                'plan: for (src, list) in &plan {
                    if *src != tgts[0] {
                        continue;
                    }
                    for &(iid2, _) in list {
                        if iid2 == iid {
                            break 'plan;
                        }
                        if matches!(agu.instr(iid2).op, Op::SendStAddr { .. })
                            && send_array(&p.module, agu, iid2) == my_arr
                        {
                            store_before = true;
                            break 'plan;
                        }
                    }
                }
                if store_before {
                    refused_instrs.insert(iid);
                    result
                        .refused
                        .push((mem, "load spec behind a same-array store".into()));
                    continue;
                }
            }
        }
    }
    // (2) address-slice hoistability, availability-aware: a hoisted load's
    // AGU consume moves with it (its value becomes available at the spec
    // block for later requests, e.g. `A[w]` with `w = idx[i]`). Iterate to
    // a fixpoint because refusing one request can invalidate another's
    // slice.
    loop {
        let mut changed = false;
        let agu = &p.module.funcs[agu_idx];
        // consume result per mem (AGU side)
        let consume_result: HashMap<u32, ValueId> = {
            let mut map = HashMap::new();
            for b in &agu.blocks {
                for &iid in &b.instrs {
                    if let Op::ConsumeVal { mem, .. } = agu.instr(iid).op {
                        if let Some(r) = agu.instr(iid).result {
                            map.insert(mem, r);
                        }
                    }
                }
            }
            map
        };
        let mut extra: HashMap<BlockId, HashSet<ValueId>> = HashMap::new();
        for (src, list) in &plan {
            for &(iid, _home) in list {
                if refused_instrs.contains(&iid) {
                    continue;
                }
                let avail = extra.entry(*src).or_default().clone();
                if clone_slice_plan(agu, iid, *src, dom, &avail).is_none() {
                    refused_instrs.insert(iid);
                    result
                        .refused
                        .push((send_mem(agu, iid), format!("address not hoistable to {src}")));
                    changed = true;
                    continue;
                }
                if let Op::SendLdAddr { mem, .. } = agu.instr(iid).op {
                    if let Some(&r) = consume_result.get(&mem) {
                        extra.entry(*src).or_default().insert(r);
                    }
                }
            }
        }
        if !changed {
            break;
        }
    }
    // All-or-nothing per array: the per-array request stream is served in
    // ARRIVAL order, so partially hoisting ops on an array reorders
    // loads/stores relative to refused (unhoisted) ones and breaks RAW
    // disambiguation (a hoisted load can pass a same-address store left
    // behind). Any refusal on an array therefore refuses every candidate
    // op on that array — speculation degrades to plain DAE for that
    // stream, never to a mis-compile.
    {
        let agu = &p.module.funcs[agu_idx];
        let refused_arrays: HashSet<crate::ir::ArrayId> = refused_instrs
            .iter()
            .map(|&iid| send_array(&p.module, agu, iid))
            .collect();
        if !refused_arrays.is_empty() {
            for (&iid, _) in &targets {
                if refused_arrays.contains(&send_array(&p.module, agu, iid)) {
                    refused_instrs.insert(iid);
                }
            }
        }
    }

    // ---- execute the plan ----------------------------------------------------
    let mut removed: HashSet<InstrId> = HashSet::new();
    for (src, list) in &plan {
        let mut reqs: Vec<SpecReq> = Vec::new();
        for &(iid, home) in list {
            if refused_instrs.contains(&iid) {
                continue;
            }
            // clone address slice + the send itself into src
            let agu = &mut p.module.funcs[agu_idx];
            let slice = clone_slice_plan(agu, iid, *src, dom, &HashSet::new())
                .expect("checked hoistable above");
            let mut remap: HashMap<ValueId, ValueId> = HashMap::new();
            for s in slice {
                let mut op = agu.instr(s).op.clone();
                remap_op(&mut op, &remap);
                let old_res = agu.instr(s).result;
                let new_iid = agu.create_instr(op);
                agu.blocks[src.index()].instrs.push(new_iid);
                if let (Some(o), Some(n)) = (old_res, agu.instr(new_iid).result) {
                    remap.insert(o, n);
                }
            }
            let mut send_op = agu.instr(iid).op.clone();
            remap_op(&mut send_op, &remap);
            let new_send = agu.create_instr(send_op);
            agu.blocks[src.index()].instrs.push(new_send);
            if !removed.contains(&iid) {
                super::detach_instr(agu, iid);
                removed.insert(iid);
            }
            // a hoisted load's AGU consume moves along (right after the
            // send) so its value stays balanced and available here for
            // later requests' address slices
            if let Op::SendLdAddr { mem, .. } = agu.instr(new_send).op {
                let mut found = None;
                'c: for (bi, b) in agu.blocks.iter().enumerate() {
                    for (pos, &ci) in b.instrs.iter().enumerate() {
                        if let Op::ConsumeVal { mem: m2, .. } = agu.instr(ci).op {
                            if m2 == mem {
                                found = Some((bi, pos, ci));
                                break 'c;
                            }
                        }
                    }
                }
                if let Some((bi, pos, ci)) = found {
                    if bi != src.index() {
                        agu.blocks[bi].instrs.remove(pos);
                        agu.blocks[src.index()].instrs.push(ci);
                    }
                }
            }
            let (mem, is_store, arr) = {
                let agu = &p.module.funcs[agu_idx];
                match agu.instr(new_send).op {
                    Op::SendLdAddr { chan, mem, .. } => {
                        (mem, false, p.module.chan(chan).arr)
                    }
                    Op::SendStAddr { chan, mem, .. } => {
                        (mem, true, p.module.chan(chan).arr)
                    }
                    _ => unreachable!(),
                }
            };
            reqs.push(SpecReq { mem, is_store, arr, true_bb: home });
        }
        if !reqs.is_empty() {
            result.map.push((*src, reqs));
        }
    }

    result
}

fn send_mem(f: &Function, iid: InstrId) -> u32 {
    match f.instr(iid).op {
        Op::SendLdAddr { mem, .. } | Op::SendStAddr { mem, .. } => mem,
        _ => panic!("not a send"),
    }
}

fn send_array(m: &crate::ir::Module, f: &Function, iid: InstrId) -> crate::ir::ArrayId {
    match f.instr(iid).op {
        Op::SendLdAddr { chan, .. } | Op::SendStAddr { chan, .. } => m.chan(chan).arr,
        _ => panic!("not a send"),
    }
}

/// The Algorithm 1 traversal region: blocks reachable from `src` in
/// reverse post-order, staying inside `src`'s innermost loop, skipping
/// backedges and edges into inner-loop headers ("we do not enter loops
/// other than the innermost loop containing srcBB", §5.1). The second
/// return is true when the frontier touched an inner-loop header — such
/// sources are skipped wholesale (pending-list scans cannot cross an
/// opaque inner loop soundly).
pub fn spec_region(
    f: &Function,
    src: BlockId,
    dom: &DomTree,
    loops: &LoopInfo,
) -> (Vec<BlockId>, bool) {
    let own_loop = loops.innermost_idx(src);
    let in_scope = |b: BlockId| -> bool {
        match own_loop {
            Some(li) => loops.loops[li].contains(b),
            None => true,
        }
    };
    let enters_inner = std::cell::Cell::new(false);
    let region = crate::analysis::rpo::reverse_post_order_from(f, src, &|a, b| {
        if dom.dominates(b, a) {
            return true; // backedge
        }
        if !in_scope(b) {
            return true; // leaves the loop (exit edge)
        }
        // entering a loop that is not src's innermost loop?
        if loops.is_header(b) && loops.innermost_idx(b) != own_loop {
            enters_inner.set(true);
            return true;
        }
        false
    });
    (region, enters_inner.get())
}

/// Can a path reach `target` from `start` (forward edges, within scope)
/// while avoiding every block in `avoid`?
fn reachable_avoiding(
    f: &Function,
    start: BlockId,
    target: BlockId,
    avoid: &[BlockId],
    dom: &DomTree,
) -> bool {
    if avoid.contains(&start) {
        return false;
    }
    let mut seen = vec![false; f.num_blocks()];
    let mut stack = vec![start];
    seen[start.index()] = true;
    while let Some(b) = stack.pop() {
        if b == target {
            return true;
        }
        for s in f.succs(b) {
            if dom.dominates(s, b) {
                continue; // backedge
            }
            if avoid.contains(&s) || seen[s.index()] {
                continue;
            }
            seen[s.index()] = true;
            stack.push(s);
        }
    }
    false
}

/// Plan the clone of `send`'s address slice at the end of `src`: the
/// instructions (in dependency order) that must be duplicated because
/// their definitions are not available at `src`. Returns `None` if the
/// slice is not hoistable (φ, channel op, or side effect in the way).
fn clone_slice_plan(
    f: &Function,
    send: InstrId,
    src: BlockId,
    dom: &DomTree,
    extra: &HashSet<ValueId>,
) -> Option<Vec<InstrId>> {
    let instr_blocks = super::instr_blocks(f);
    // available at end of src := def block strictly dominates src, or def
    // is inside src itself, or an earlier hoist will have moved it there
    // (`extra` — consume results of already-hoisted loads).
    let available = |v: ValueId| -> bool {
        if extra.contains(&v) {
            return true;
        }
        match f.value(v).def {
            ValueDef::Param(_) => true,
            ValueDef::Instr(iid) => match instr_blocks[iid.index()] {
                Some(bb) => bb == src || dom.strictly_dominates(bb, src),
                None => false, // detached
            },
        }
    };

    let idx = match f.instr(send).op {
        Op::SendLdAddr { idx, .. } | Op::SendStAddr { idx, .. } => idx,
        _ => return None,
    };

    let mut order: Vec<InstrId> = Vec::new();
    let mut seen: HashSet<InstrId> = HashSet::new();

    // DFS producing dependency (post-) order.
    fn visit(
        f: &Function,
        v: ValueId,
        available: &dyn Fn(ValueId) -> bool,
        seen: &mut HashSet<InstrId>,
        order: &mut Vec<InstrId>,
    ) -> bool {
        if available(v) {
            return true;
        }
        let ValueDef::Instr(iid) = f.value(v).def else { return false };
        if seen.contains(&iid) {
            return true;
        }
        let op = &f.instr(iid).op;
        let pure = matches!(
            op,
            Op::ConstI(_)
                | Op::ConstF(_)
                | Op::ConstB(_)
                | Op::IBin(..)
                | Op::FBin(..)
                | Op::ICmp(..)
                | Op::FCmp(..)
                | Op::Not(_)
                | Op::Select { .. }
                | Op::IToF(_)
                | Op::FToI(_)
        );
        if !pure {
            return false; // φ, consume, load… not cloneable
        }
        seen.insert(iid);
        for u in op.uses() {
            if !visit(f, u, available, seen, order) {
                return false;
            }
        }
        order.push(iid);
        true
    }

    if visit(f, idx, &available, &mut seen, &mut order) {
        Some(order)
    } else {
        None
    }
}

fn remap_op(op: &mut Op, remap: &HashMap<ValueId, ValueId>) {
    for (old, new) in remap {
        op.replace_use(*old, *new);
    }
}

#[cfg(test)]
pub mod tests {
    use super::*;
    use crate::analysis::LodAnalysis;
    use crate::ir::parser::parse_single;
    use crate::transform::decouple::decouple;

    /// Paper Figure 3a: three stores under nested LoD branches.
    pub const FIG3: &str = r#"
array @A : i64[100]

func @fig3(%n: i64) {
entry:
  %c1 = const.i 1
  br header
header:
  %i = phi i64 [entry: %c1], [latch: %inext]
  %nm1 = sub.i %n, %c1
  %cc = icmp.lt %i, %nm1
  condbr %cc, body, exit
body:
  %a = load @A[%i]
  %zero = const.i 0
  %p = icmp.gt %a, %zero
  condbr %p, pos, neg
pos:
  %max1 = const.i 50
  %q = icmp.lt %a, %max1
  condbr %q, st0b, st1b
st0b:
  %ip1 = add.i %i, %c1
  %av0 = add.i %a, %c1
  store @A[%ip1], %av0
  br latch
st1b:
  %im1 = sub.i %i, %c1
  %av1 = add.i %a, %c1
  store @A[%im1], %av1
  br latch
neg:
  %av2 = add.i %a, %c1
  store @A[%i], %av2
  br latch
latch:
  %inext = add.i %i, %c1
  br header
exit:
  ret
}
"#;

    #[test]
    fn fig3_hoists_all_three_stores_to_body() {
        let (m, f) = parse_single(FIG3).unwrap();
        let lod = LodAnalysis::new(&m, &f);
        // chain heads: only `body` (pos is chained behind it, §5.1.2)
        let body = BlockId(2);
        assert_eq!(lod.chain_heads, vec![body], "src={:?}", lod.src_blocks);

        let dom = DomTree::new(&f);
        let loops = LoopInfo::new(&f, &dom);
        let reach = Reachability::new(&f, &dom);
        let mut p = decouple(&m, &f, false);
        let hr = hoist_speculative_requests(&mut p, &lod, &dom, &loops, &reach);
        assert!(hr.refused.is_empty(), "{:?}", hr.refused);
        assert_eq!(hr.map.len(), 1);
        let (src, reqs) = &hr.map[0];
        assert_eq!(*src, body);
        // topological order of homes: st0b(4) and st1b(5) in RPO before?
        // region RPO from body: pos, st0b, st1b (or st1b, st0b), neg, latch.
        // All three stores hoisted; store to A[i] (mem of `neg`) last or
        // per RPO.
        assert_eq!(reqs.len(), 3);
        let homes: Vec<u32> = reqs.iter().map(|r| r.true_bb.0).collect();
        // all three homes present
        assert!(homes.contains(&4) && homes.contains(&5) && homes.contains(&6));
        // topological: pos-side stores (4,5) come before... neg(6) is a
        // sibling branch; RPO interleaving just needs consistency, checked
        // by the Lemma 6.1 property tests. Here: verify sends moved.
        let agu = p.agu_fn();
        let body_sends = agu
            .block(body)
            .instrs
            .iter()
            .filter(|&&i| agu.instr(i).op.is_send())
            .count();
        assert_eq!(body_sends, 4, "A-load send + 3 hoisted store sends");
        crate::ir::verify::verify_function(&p.module, agu).unwrap();
    }

    #[test]
    fn refuses_unhoistable_phi_address() {
        // the store address flows through a φ computed *inside* the LoD
        // region (below the spec source) — cannot clone at srcBB.
        let (m, f) = parse_single(
            r#"
array @A : i64[100]

func @phiaddr(%n: i64) {
entry:
  %c0 = const.i 0
  %c1 = const.i 1
  %c2 = const.i 2
  br header
header:
  %i = phi i64 [entry: %c0], [latch: %inext]
  %cc = icmp.lt %i, %n
  condbr %cc, body, exit
body:
  %a = load @A[%i]
  %p = icmp.gt %a, %c0
  condbr %p, inner, latch
inner:
  %par = rem.i %i, %c2
  %pp = icmp.eq %par, %c0
  condbr %pp, t, e
t:
  %x1 = add.i %i, %c1
  br join
e:
  %x2 = sub.i %i, %c1
  br join
join:
  %x = phi i64 [t: %x1], [e: %x2]
  store @A[%x], %a
  br latch
latch:
  %inext = add.i %i, %c1
  br header
exit:
  ret
}
"#,
        )
        .unwrap();
        let lod = LodAnalysis::new(&m, &f);
        let dom = DomTree::new(&f);
        let loops = LoopInfo::new(&f, &dom);
        let reach = Reachability::new(&f, &dom);
        let mut p = decouple(&m, &f, false);
        let hr = hoist_speculative_requests(&mut p, &lod, &dom, &loops, &reach);
        assert!(
            hr.refused.iter().any(|(_, why)| why.contains("not hoistable")),
            "{:?}",
            hr.refused
        );
        assert!(hr.map.is_empty(), "all-or-nothing per array");
    }
}
