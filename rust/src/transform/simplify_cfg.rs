//! Control-flow simplification (paper §3.2 step 3: "removes empty blocks
//! potentially created by DCE").
//!
//! Two rewrites, iterated to a fixed point:
//! 1. *Skip empty forwarders*: a block with no instructions and an
//!    unconditional `br` is bypassed (predecessors retarget), provided φ
//!    consistency in the target allows it.
//! 2. *Merge straight lines*: `a -> b` where `a` ends in `br b` and `b`
//!    has exactly one predecessor is folded into `a`.
//!
//! Unreachable blocks are detached (left in the arena, removed from every
//! terminator path — the printer and block counts skip them via
//! [`reachable_blocks`]).

use crate::ir::{BlockId, Function, Op, Terminator};

/// Blocks reachable from entry.
pub fn reachable_blocks(f: &Function) -> Vec<bool> {
    let mut seen = vec![false; f.num_blocks()];
    let mut stack = vec![f.entry];
    seen[f.entry.index()] = true;
    while let Some(b) = stack.pop() {
        for s in f.succs(b) {
            if !seen[s.index()] {
                seen[s.index()] = true;
                stack.push(s);
            }
        }
    }
    seen
}

/// Number of reachable blocks (the paper's "code size" unit for the CU).
pub fn num_reachable_blocks(f: &Function) -> usize {
    reachable_blocks(f).iter().filter(|&&x| x).count()
}

pub fn run(f: &mut Function) {
    loop {
        let mut changed = false;

        // 0. fold condbr with identical targets into br (ORACLE flattening
        // leaves these behind)
        for bi in 0..f.num_blocks() {
            if let Terminator::CondBr { t, f: fb, .. } = f.blocks[bi].term {
                if t == fb {
                    f.blocks[bi].term = Terminator::Br(t);
                    changed = true;
                }
            }
        }

        // 1. bypass empty forwarders
        let reach = reachable_blocks(f);
        for bi in 0..f.num_blocks() {
            let b = BlockId(bi as u32);
            if !reach[bi] || b == f.entry {
                continue;
            }
            if !f.block(b).instrs.is_empty() {
                continue;
            }
            let Terminator::Br(target) = f.block(b).term else { continue };
            if target == b {
                continue;
            }
            // φs in target must not distinguish between b's preds and
            // target's other preds; bypass only if target has no φs, or if
            // b has exactly one predecessor (then the φ arm label can be
            // rewritten).
            let preds = f.preds();
            let bpreds: Vec<BlockId> = preds[b.index()].clone();
            let target_has_phis = f
                .block(target)
                .instrs
                .iter()
                .any(|&i| matches!(f.instr(i).op, Op::Phi { .. }));
            if target_has_phis && bpreds.len() != 1 {
                continue;
            }
            if target_has_phis {
                // single pred p: retarget φ arms naming b to p — but only
                // if p is not already an incoming block of the φ.
                let p = bpreds[0];
                let mut conflict = false;
                for &iid in &f.block(target).instrs {
                    if let Op::Phi { incomings, .. } = &f.instr(iid).op {
                        if incomings.iter().any(|(bb, _)| *bb == p) {
                            conflict = true;
                        }
                    }
                }
                if conflict {
                    continue;
                }
                let t_instrs = f.block(target).instrs.clone();
                for iid in t_instrs {
                    if let Op::Phi { incomings, .. } = &mut f.instr_mut(iid).op {
                        for (bb, _) in incomings.iter_mut() {
                            if *bb == b {
                                *bb = p;
                            }
                        }
                    }
                }
            }
            for p in bpreds {
                f.block_mut(p).term.replace_succ(b, target);
            }
            // detach b
            f.block_mut(b).term = Terminator::Ret;
            f.block_mut(b).instrs.clear();
            changed = true;
        }

        // 2. merge straight-line pairs
        let reach = reachable_blocks(f);
        let preds = f.preds();
        for ai in 0..f.num_blocks() {
            let a = BlockId(ai as u32);
            if !reach[ai] {
                continue;
            }
            let Terminator::Br(bq) = f.block(a).term else { continue };
            if bq == a || bq == f.entry {
                continue;
            }
            let reach_now = reachable_blocks(f);
            if !reach_now[bq.index()] {
                continue;
            }
            if preds[bq.index()].len() != 1 {
                continue;
            }
            // b must not start with φs (single pred ⇒ φs are trivial; fold
            // them into copies by replacing uses).
            let binstrs = f.block(bq).instrs.clone();
            let mut trivial_phi_rewrites = Vec::new();
            let mut ok = true;
            for &iid in &binstrs {
                if let Op::Phi { incomings, .. } = &f.instr(iid).op {
                    if incomings.len() == 1 {
                        trivial_phi_rewrites
                            .push((f.instr(iid).result.unwrap(), incomings[0].1));
                    } else {
                        ok = false;
                    }
                }
            }
            if !ok {
                continue;
            }
            for (old, new) in trivial_phi_rewrites {
                f.replace_all_uses(old, new);
            }
            let moved: Vec<_> = binstrs
                .iter()
                .copied()
                .filter(|&i| !matches!(f.instr(i).op, Op::Phi { .. }))
                .collect();
            let bterm = f.block(bq).term.clone();
            f.block_mut(bq).instrs.clear();
            f.block_mut(bq).term = Terminator::Ret;
            f.block_mut(a).instrs.extend(moved);
            f.block_mut(a).term = bterm;
            // φs in b's successors referring to b now come from a.
            for s in f.succs(a) {
                let s_instrs = f.block(s).instrs.clone();
                for iid in s_instrs {
                    if let Op::Phi { incomings, .. } = &mut f.instr_mut(iid).op {
                        for (bb, _) in incomings.iter_mut() {
                            if *bb == bq {
                                *bb = a;
                            }
                        }
                    }
                }
            }
            changed = true;
        }

        if !changed {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_single;

    #[test]
    fn merges_chain_and_removes_empty() {
        let (_m, mut f) = parse_single(
            r#"
func @f(%c: b1) {
entry:
  condbr %c, a, b
a:
  br mid
mid:
  br join
b:
  br join
join:
  ret
}
"#,
        )
        .unwrap();
        run(&mut f);
        // a, mid are empty forwarders; everything collapses around the
        // diamond: entry -> {join, join}? `a` chain bypassed.
        let n = num_reachable_blocks(&f);
        assert!(n <= 2, "expected collapse, got {n} blocks");
    }

    #[test]
    fn preserves_phi_semantics() {
        let (_m, mut f) = parse_single(
            r#"
func @f(%c: b1, %x: i64, %y: i64) {
entry:
  condbr %c, a, b
a:
  br join
b:
  br join
join:
  %v = phi i64 [a: %x], [b: %y]
  %c0 = const.i 0
  %p = icmp.gt %v, %c0
  condbr %p, t, e
t:
  br e
e:
  ret
}
"#,
        )
        .unwrap();
        run(&mut f);
        // at most one of a/b can be bypassed into entry (the second would
        // make both φ arms come from `entry`); the φ itself must survive
        // with two incomings.
        let phis: Vec<_> = f
            .instrs
            .iter()
            .filter_map(|i| match &i.op {
                Op::Phi { incomings, .. } => Some(incomings.len()),
                _ => None,
            })
            .collect();
        assert_eq!(phis, vec![2], "φ must keep both arms");
        crate::ir::verify::verify_function(&crate::ir::Module::new(), &f).unwrap();
    }
}
