//! Tests for the `sim/mod.rs` helpers (`memory_diff`, `zero_memory`)
//! and a minimum-capacity stress run: `chan_cap=1`, `ld_q=1`, `st_q=1`
//! must not deadlock any default kernel and must preserve results.

use dae_spec::coordinator::build_workload;
use dae_spec::ir::parser::parse_module;
use dae_spec::ir::types::Val;
use dae_spec::sim::machine::simulate;
use dae_spec::sim::{interpret, memory_diff, zero_memory, MachineConfig, Memory};
use dae_spec::transform::{build, Arch};

#[test]
fn memory_diff_is_bit_exact_on_nan() {
    let nan1 = f64::NAN;
    let nan2 = f64::from_bits(nan1.to_bits() ^ 1); // a different NaN payload
    assert!(nan1.is_nan() && nan2.is_nan());

    let a: Memory = vec![vec![Val::F(nan1), Val::F(1.0)]];
    let same: Memory = vec![vec![Val::F(nan1), Val::F(1.0)]];
    // identical bit patterns — NaN == NaN under bits_eq, unlike IEEE ==
    assert_eq!(memory_diff(&a, &same), None);

    let other_payload: Memory = vec![vec![Val::F(nan2), Val::F(1.0)]];
    assert_eq!(memory_diff(&a, &other_payload), Some((0, 0)));

    // +0.0 and -0.0 differ bitwise even though they compare IEEE-equal
    let pz: Memory = vec![vec![Val::F(0.0)]];
    let nz: Memory = vec![vec![Val::F(-0.0)]];
    assert_eq!(memory_diff(&pz, &nz), Some((0, 0)));
}

#[test]
fn memory_diff_reports_first_mismatch_index() {
    let mk = || -> Memory {
        vec![
            (0..4).map(Val::I).collect(),
            (0..6).map(|i| Val::I(i * 10)).collect(),
        ]
    };
    let a = mk();
    let mut b = mk();
    assert_eq!(memory_diff(&a, &b), None);
    b[1][3] = Val::I(-7);
    assert_eq!(memory_diff(&a, &b), Some((1, 3)));
    // an earlier mismatch wins
    b[0][2] = Val::I(99);
    assert_eq!(memory_diff(&a, &b), Some((0, 2)));
}

#[test]
fn zero_memory_types_elements_per_array() {
    let m = parse_module(
        r#"
array @ints : i64[4]
array @floats : f64[3]

func @noop() {
entry:
  ret
}
"#,
    )
    .unwrap();
    let mem = zero_memory(&m);
    assert_eq!(mem.len(), 2);
    assert_eq!(mem[0].len(), 4);
    assert_eq!(mem[1].len(), 3);
    for v in &mem[0] {
        assert!(v.bits_eq(Val::I(0)), "i64 array zeroes as integer 0, got {v:?}");
    }
    for v in &mem[1] {
        assert!(v.bits_eq(Val::F(0.0)), "f64 array zeroes as float 0.0, got {v:?}");
    }
}

#[test]
fn min_capacity_stress_completes_and_matches() {
    // Minimum queue everywhere: 1-deep channels, 1 load in flight,
    // 1 store slot. Channel capacity is *functional* backpressure — a
    // full FIFO blocks its producer until the consumer pops — so this
    // pins that the scheduler drains every blocked-producer cycle: the
    // machine must still terminate (no channel deadlock) and commit
    // exactly the reference memory.
    let cfg = MachineConfig {
        chan_cap: 1,
        ld_q: 1,
        st_q: 1,
        ..MachineConfig::default()
    };
    for kernel in ["hist", "thr"] {
        let w = build_workload(kernel, 11, None).unwrap();
        let reference = interpret(
            &w.module,
            &w.module.funcs[0],
            &w.args,
            w.memory.clone(),
            cfg.max_dyn_instrs,
        )
        .unwrap();
        for arch in [Arch::Sta, Arch::Dae, Arch::Spec] {
            let c = build(&w.module, 0, arch).unwrap();
            let sim = simulate(&c, &w.args, w.memory.clone(), &cfg)
                .unwrap_or_else(|e| panic!("{kernel}/{arch:?} at min capacity: {e:#}"));
            assert_eq!(
                memory_diff(&sim.memory, &reference.memory),
                None,
                "{kernel}/{arch:?} diverges at minimum queue capacity"
            );
        }
    }
}

#[test]
fn chan_cap_is_functional_only_backpressure() {
    // Timestamps are computed from data dependencies, not from host
    // scheduling order, so capacity-induced producer blocking must not
    // change a single reported number — only host-side scheduling.
    // cap=1 (maximum backpressure) vs the default cap must agree on
    // cycles, instruction counts and memory, across architectures.
    let tight = MachineConfig { chan_cap: 1, ..MachineConfig::default() };
    let roomy = MachineConfig::default();
    for kernel in ["hist", "thr"] {
        let w = build_workload(kernel, 7, None).unwrap();
        for arch in [Arch::Sta, Arch::Dae, Arch::Spec] {
            let c = build(&w.module, 0, arch).unwrap();
            let a = simulate(&c, &w.args, w.memory.clone(), &tight)
                .unwrap_or_else(|e| panic!("{kernel}/{arch:?} cap=1: {e:#}"));
            let b = simulate(&c, &w.args, w.memory.clone(), &roomy)
                .unwrap_or_else(|e| panic!("{kernel}/{arch:?} default cap: {e:#}"));
            assert_eq!(a.cycles, b.cycles, "{kernel}/{arch:?}: cap changed cycles");
            assert_eq!(
                a.dyn_instrs, b.dyn_instrs,
                "{kernel}/{arch:?}: cap changed instruction count"
            );
            assert_eq!(
                memory_diff(&a.memory, &b.memory),
                None,
                "{kernel}/{arch:?}: cap changed final memory"
            );
        }
    }
}
