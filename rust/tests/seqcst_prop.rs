//! Property tests for Lemma 6.1 (sequential consistency) and liveness.
//!
//! A seeded generator emits random *structured* programs — a loop over
//! nested if/else regions with guarded stores, where guards are either
//! LoD (compare a value loaded from the stored array) or pure — exactly
//! the reducible-CFG class the paper's transformation targets. For every
//! program and every architecture we check:
//!
//! 1. **safety** — STA/DAE/SPEC final memory equals the reference
//!    interpreter's (the DU additionally asserts, per array, that the
//!    k-th store value pairs with the k-th store request: any ordering
//!    bug in Algorithms 1-3 trips it immediately);
//! 2. **liveness** — the co-simulation terminates (the machine's
//!    no-progress detector would report deadlock otherwise);
//! 3. the edge-local Algorithm 2 planner agrees with the paper-literal
//!    all-paths enumeration (`poison_plan_naive`).
//!
//! The generator intentionally produces cases where speculation is
//! partially *refused* (φ addresses, source chains): the transform must
//! degrade gracefully, never silently mis-compile.

use dae_spec::sim::machine::simulate;
use dae_spec::sim::{interpret, memory_diff, zero_memory, MachineConfig};
use dae_spec::transform::poison::{plan_placements_for_tests, poison_plan_naive};
use dae_spec::transform::{build, Arch, Compiled};
use dae_spec::util::Rng;
use std::fmt::Write;

const ARRAY_N: usize = 64;
const TRIPS: i64 = 24;

struct Gen {
    rng: Rng,
    src: String,
    next_val: u32,
    next_block: u32,
    stores: u32,
}

impl Gen {
    fn v(&mut self, prefix: &str) -> String {
        self.next_val += 1;
        format!("%{prefix}{}", self.next_val)
    }

    fn bb(&mut self, prefix: &str) -> String {
        self.next_block += 1;
        format!("{prefix}{}", self.next_block)
    }

    /// Emit an in-bounds address expression over `i`; returns the value
    /// name. Offsets keep addresses within [0, ARRAY_N).
    fn addr(&mut self, indent: &str) -> String {
        let c = self.rng.range_i64(0, 8);
        let a = self.v("ao");
        let b = self.v("aa");
        let m = self.v("am");
        let n = self.v("an");
        let _ = writeln!(self.src, "{indent}{a} = const.i {c}");
        let _ = writeln!(self.src, "{indent}{b} = add.i %i, {a}");
        let _ = writeln!(self.src, "{indent}{m} = const.i {}", ARRAY_N);
        let _ = writeln!(self.src, "{indent}{n} = rem.i {b}, {m}");
        n
    }

    /// Emit a region of statements ending with `br {exit}`.
    /// `depth` bounds nesting.
    fn region(&mut self, exit: &str, depth: u32) {
        // 1-3 statements
        let n_stmts = 1 + self.rng.below(2 + depth as u64 % 2) as usize;
        for _ in 0..n_stmts {
            if self.stores >= 6 {
                break;
            }
            let pick = self.rng.below(100);
            if pick < 45 || depth == 0 {
                // guarded or plain store
                self.stores += 1;
                let addr = self.addr("  ");
                let cv = self.v("sc");
                let val = self.v("sv");
                let _ = writeln!(self.src, "  {cv} = const.i {}", self.rng.range_i64(1, 9));
                let _ = writeln!(self.src, "  {val} = add.i %i, {cv}");
                let _ = writeln!(self.src, "  store @A[{addr}], {val}");
            } else {
                // if (guard) { region } [else { region }]
                let then_bb = self.bb("t");
                let else_bb = self.bb("e");
                let join_bb = self.bb("j");
                let has_else = self.rng.chance(0.5);
                let guard = if self.rng.chance(0.7) {
                    // LoD guard: compare a loaded A value
                    let addr = self.addr("  ");
                    let lv = self.v("g");
                    let cc = self.v("gc");
                    let p = self.v("gp");
                    let _ = writeln!(self.src, "  {lv} = load @A[{addr}]");
                    let _ =
                        writeln!(self.src, "  {cc} = const.i {}", self.rng.range_i64(0, 20));
                    let cmp = ["lt", "gt", "le", "ge", "eq", "ne"]
                        [self.rng.below(6) as usize];
                    let _ = writeln!(self.src, "  {p} = icmp.{cmp} {lv}, {cc}");
                    p
                } else {
                    // pure guard: i % k == c
                    let k = self.v("pk");
                    let r = self.v("pr");
                    let c = self.v("pc");
                    let p = self.v("pp");
                    let kk = self.rng.range_i64(2, 5);
                    let _ = writeln!(self.src, "  {k} = const.i {kk}");
                    let _ = writeln!(self.src, "  {r} = rem.i %i, {k}");
                    let _ =
                        writeln!(self.src, "  {c} = const.i {}", self.rng.range_i64(0, kk));
                    let _ = writeln!(self.src, "  {p} = icmp.eq {r}, {c}");
                    p
                };
                let else_target = if has_else { else_bb.clone() } else { join_bb.clone() };
                let _ = writeln!(self.src, "  condbr {guard}, {then_bb}, {else_target}");
                let _ = writeln!(self.src, "{then_bb}:");
                self.region(&join_bb, depth.saturating_sub(1));
                if has_else {
                    let _ = writeln!(self.src, "{else_bb}:");
                    self.region(&join_bb, depth.saturating_sub(1));
                }
                let _ = writeln!(self.src, "{join_bb}:");
            }
        }
        let _ = writeln!(self.src, "  br {exit}");
    }
}

fn generate(seed: u64) -> (String, u32) {
    let mut g = Gen {
        rng: Rng::new(seed),
        src: String::new(),
        next_val: 0,
        next_block: 0,
        stores: 0,
    };
    let _ = writeln!(g.src, "array @A : i64[{ARRAY_N}]\n");
    let _ = writeln!(g.src, "func @prop(%n: i64) {{");
    let _ = writeln!(g.src, "entry:\n  %c0 = const.i 0\n  br header");
    let _ = writeln!(
        g.src,
        "header:\n  %i = phi i64 [entry: %c0], [latch: %inext]\n  %cc = icmp.lt %i, %n\n  condbr %cc, body, exit"
    );
    let _ = writeln!(g.src, "body:");
    g.region("latch", 2);
    let _ = writeln!(
        g.src,
        "latch:\n  %c1z = const.i 1\n  %inext = add.i %i, %c1z\n  br header"
    );
    let _ = writeln!(g.src, "exit:\n  ret\n}}");
    (g.src, g.stores)
}

#[test]
fn lemma_6_1_sequential_consistency_and_liveness() {
    let cfg = MachineConfig::default();
    let mut speculated_cases = 0;
    let mut refused_cases = 0;
    let n_cases = 300;
    for seed in 0..n_cases {
        let (src, stores) = generate(seed);
        if stores == 0 {
            continue;
        }
        let m = dae_spec::ir::parser::parse_module(&src)
            .unwrap_or_else(|e| panic!("seed {seed}: parse: {e}\n{src}"));
        // seeded initial memory
        let mut mem = zero_memory(&m);
        let mut rng = Rng::new(seed ^ 0xDA7A);
        for v in mem[0].iter_mut() {
            *v = dae_spec::ir::types::Val::I(rng.range_i64(-5, 25));
        }
        let reference = interpret(&m, &m.funcs[0], &[dae_spec::ir::types::Val::I(TRIPS)], mem.clone(), 10_000_000)
            .unwrap_or_else(|e| panic!("seed {seed}: interp: {e}\n{src}"));

        for arch in [Arch::Sta, Arch::Dae, Arch::Spec] {
            let c = build(&m, 0, arch)
                .unwrap_or_else(|e| panic!("seed {seed}/{arch:?}: build: {e}\n{src}"));
            if arch == Arch::Spec {
                if let Compiled::Dae { map, stats, .. } = &c {
                    let n: usize =
                        map.as_ref().map(|m| m.iter().map(|(_, r)| r.len()).sum()).unwrap_or(0);
                    if n > 0 {
                        speculated_cases += 1;
                    }
                    if !stats.refused.is_empty() {
                        refused_cases += 1;
                    }
                }
            }
            // liveness: simulate() bails on deadlock; safety: the DU
            // bails on store-stream order violations.
            let sim = simulate(&c, &[dae_spec::ir::types::Val::I(TRIPS)], mem.clone(), &cfg)
                .unwrap_or_else(|e| panic!("seed {seed}/{arch:?}: sim: {e}\n{src}"));
            if let Some((ai, i)) = memory_diff(&sim.memory, &reference.memory) {
                panic!(
                    "seed {seed}/{arch:?}: memory diverges at array {ai}[{i}]\n{src}"
                );
            }
        }

        // cross-validate the edge-local planner against the paper-literal
        // all-paths enumeration
        let spec = build(&m, 0, Arch::Spec).unwrap();
        if let Compiled::Dae { map: Some(map), .. } = &spec {
            if !map.is_empty() {
                // recompute on a pristine CU (pre-poison)
                let lod = dae_spec::analysis::LodAnalysis::new(&m, &m.funcs[0]);
                let dom = dae_spec::analysis::DomTree::new(&m.funcs[0]);
                let loops = dae_spec::analysis::LoopInfo::new(&m.funcs[0], &dom);
                let reach = dae_spec::analysis::Reachability::new(&m.funcs[0], &dom);
                let mut p = dae_spec::transform::decouple(&m, &m.funcs[0], false);
                let hr = dae_spec::transform::hoist_speculative_requests(
                    &mut p, &lod, &dom, &loops, &reach,
                );
                let cu = &p.module.funcs[p.cu];
                let fast = plan_placements_for_tests(cu, &hr.map)
                    .unwrap_or_else(|e| panic!("seed {seed}: plan: {e}"));
                let naive = poison_plan_naive(cu, &hr.map, 200_000)
                    .unwrap_or_else(|e| panic!("seed {seed}: naive: {e}"));
                let naive_set: std::collections::BTreeSet<(u32, u32)> =
                    naive.iter().map(|&(_, to, mem)| (to, mem)).collect();
                assert_eq!(
                    fast, naive_set,
                    "seed {seed}: edge-local and all-paths planners disagree\n{src}"
                );
            }
        }
    }
    eprintln!(
        "prop: {n_cases} programs, {speculated_cases} with speculation, {refused_cases} with partial refusal"
    );
    assert!(speculated_cases > 50, "generator should produce speculation-rich programs");
}

#[test]
fn oracle_terminates_on_random_programs() {
    // ORACLE is functionally wrong by design; it must still build and
    // terminate (liveness) on every input.
    let cfg = MachineConfig::default();
    for seed in 0..60 {
        let (src, stores) = generate(seed);
        if stores == 0 {
            continue;
        }
        let m = dae_spec::ir::parser::parse_module(&src).unwrap();
        let mem = zero_memory(&m);
        let c = build(&m, 0, Arch::Oracle)
            .unwrap_or_else(|e| panic!("seed {seed}: oracle build: {e}"));
        simulate(&c, &[dae_spec::ir::types::Val::I(TRIPS)], mem, &cfg)
            .unwrap_or_else(|e| panic!("seed {seed}: oracle sim: {e}"));
    }
}
