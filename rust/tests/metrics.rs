//! Observability pins: the metrics layer must be timing-neutral
//! (cycles, memory and commit logs bit-identical with metrics on or
//! off, on every kernel × arch), deterministic (same seed →
//! byte-identical `profile --json` and Perfetto documents), and
//! correctly reset across session reuse — a failed run must not leak
//! counters into the next one.

use dae_spec::coordinator::build_workload;
use dae_spec::coordinator::profile::profile_json;
use dae_spec::fault::{FaultInjector, FaultPlan};
use dae_spec::metrics::MetricsSummary;
use dae_spec::sim::{memory_diff, simulate, MachineConfig, SimSession};
use dae_spec::transform::{build, Arch};
use dae_spec::workloads::PAPER_KERNELS;

fn kernels() -> Vec<&'static str> {
    let mut ks: Vec<&str> = PAPER_KERNELS.to_vec();
    ks.push("nested3");
    ks
}

/// The tentpole pin: enabling `MachineConfig::metrics` observes the
/// machine without perturbing it — every reported number, the final
/// memory and the commit log are bit-identical to a metrics-off run.
#[test]
fn metrics_are_timing_neutral_everywhere() {
    let off = MachineConfig::default();
    let on = MachineConfig { metrics: true, ..MachineConfig::default() };
    for kernel in kernels() {
        let w = build_workload(kernel, 2026, None).unwrap();
        for arch in [Arch::Sta, Arch::Dae, Arch::Spec] {
            let c = build(&w.module, 0, arch).unwrap();
            let a = simulate(&c, &w.args, w.memory.clone(), &off)
                .unwrap_or_else(|e| panic!("{kernel}/{arch:?} metrics off: {e:#}"));
            let b = simulate(&c, &w.args, w.memory.clone(), &on)
                .unwrap_or_else(|e| panic!("{kernel}/{arch:?} metrics on: {e:#}"));
            assert_eq!(a.cycles, b.cycles, "{kernel}/{arch:?}: cycles differ");
            assert_eq!(a.dyn_instrs, b.dyn_instrs, "{kernel}/{arch:?}: dyn_instrs differ");
            assert_eq!(
                a.stores_committed, b.stores_committed,
                "{kernel}/{arch:?}: stores_committed differ"
            );
            assert_eq!(
                a.stores_poisoned, b.stores_poisoned,
                "{kernel}/{arch:?}: stores_poisoned differ"
            );
            assert_eq!(a.misspec_rate, b.misspec_rate, "{kernel}/{arch:?}: misspec_rate");
            assert_eq!(
                memory_diff(&a.memory, &b.memory),
                None,
                "{kernel}/{arch:?}: memory differs with metrics on"
            );
            assert_eq!(a.commit_log, b.commit_log, "{kernel}/{arch:?}: commit log differs");
            assert!(a.metrics.is_none(), "{kernel}/{arch:?}: metrics off but summary present");
            let m = b.metrics.as_ref().unwrap_or_else(|| {
                panic!("{kernel}/{arch:?}: metrics on but no summary")
            });
            assert_eq!(m.cycles, b.cycles, "{kernel}/{arch:?}: summary cycle count");
            let busy: u64 = m.units.iter().map(|u| u.busy_instrs).sum();
            assert_eq!(busy, b.dyn_instrs, "{kernel}/{arch:?}: per-unit busy vs dyn_instrs");
        }
    }
}

/// Same seed → byte-identical `dae-spec profile --json` document.
#[test]
fn profile_json_is_byte_deterministic() {
    let cfg = MachineConfig::default();
    let archs = [Arch::Sta, Arch::Dae, Arch::Spec];
    let a = profile_json("hist", 2026, None, &archs, &cfg).unwrap().render();
    let b = profile_json("hist", 2026, None, &archs, &cfg).unwrap().render();
    assert_eq!(a, b, "profile document differs between identical runs");
    assert!(a.contains("dae-spec-profile/v1"), "schema tag missing");
    assert!(a.contains("mean_slack"), "slack summary missing");
}

/// The acceptance probe: on `hist`, SPEC shows real speculation —
/// nonzero speculated store requests, poisons, poison rate and positive
/// decoupling slack — while DAE and STA show none of it.
#[test]
fn spec_reports_slack_and_poisons_hist() {
    let cfg = MachineConfig { metrics: true, ..MachineConfig::default() };
    let w = build_workload("hist", 2026, None).unwrap();

    let run = |arch: Arch| -> MetricsSummary {
        let c = build(&w.module, 0, arch).unwrap();
        simulate(&c, &w.args, w.memory.clone(), &cfg)
            .unwrap_or_else(|e| panic!("hist/{arch:?}: {e:#}"))
            .metrics
            .expect("metrics enabled")
    };

    let spec = run(Arch::Spec);
    assert!(spec.speculation.spec_store_reqs > 0, "SPEC issued no speculated stores");
    assert!(spec.speculation.poisons > 0, "hist misspec produced no poisons");
    assert!(spec.speculation.poison_rate > 0.0, "zero poison rate");
    assert!(spec.speculation.discarded_cycles > 0, "poisons discarded no residency");
    assert!(!spec.speculation.per_array.is_empty(), "no per-array poison attribution");
    assert!(!spec.slack.is_empty(), "no slack pairings recorded");
    assert!(
        spec.slack.iter().any(|s| s.mean_slack > 0.0),
        "SPEC shows no positive decoupling slack: {:?}",
        spec.slack
    );
    assert!(spec.mlp > 0.0, "zero MLP");
    assert!(!spec.channels.is_empty() && !spec.lsqs.is_empty());

    for arch in [Arch::Sta, Arch::Dae] {
        let m = run(arch);
        assert_eq!(m.speculation.spec_store_reqs, 0, "{arch:?} reports speculated stores");
        assert_eq!(m.speculation.poisons, 0, "{arch:?} reports poisons");
        assert_eq!(m.speculation.poison_rate, 0.0, "{arch:?} poison rate");
        assert!(m.mlp > 0.0, "{arch:?}: zero MLP");
    }
}

/// Session reuse: counters reset on entry, so a clean run after a
/// wedged (failed) run reports exactly the same summary as the first
/// clean run — nothing from the aborted run leaks through.
#[test]
fn session_reuse_resets_counters_after_failed_run() {
    let cfg = MachineConfig { metrics: true, ..MachineConfig::default() };
    let w = build_workload("hist", 2026, None).unwrap();
    let c = build(&w.module, 0, Arch::Spec).unwrap();
    let mut sess = SimSession::new(&c, &cfg, w.memory.clone()).unwrap();

    sess.run(&w.args).unwrap();
    let first = sess.metrics_summary().cloned().expect("metrics enabled");

    sess.set_fault(Some(FaultInjector::new(FaultPlan::wedge())));
    assert!(sess.run(&w.args).is_err(), "wedge plan should stall the machine");
    assert!(
        sess.metrics_summary().is_none(),
        "failed run must not publish a summary"
    );

    sess.set_fault(None);
    sess.run(&w.args).unwrap();
    let third = sess.metrics_summary().cloned().expect("metrics enabled");
    assert_eq!(first, third, "counters leaked across a failed run");
}

/// Perfetto export is deterministic across sessions and carries the
/// expected structure: named lanes, counter tracks, poison instants.
#[test]
fn perfetto_export_is_deterministic_and_structured() {
    let cfg = MachineConfig { metrics: true, trace: true, ..MachineConfig::default() };
    let w = build_workload("hist", 2026, None).unwrap();
    let c = build(&w.module, 0, Arch::Spec).unwrap();

    let export = || {
        let mut sess = SimSession::new(&c, &cfg, w.memory.clone()).unwrap();
        sess.run(&w.args).unwrap();
        sess.perfetto("hist/SPEC").expect("trace enabled").render()
    };
    let a = export();
    let b = export();
    assert_eq!(a, b, "perfetto document differs between identical runs");
    assert!(a.contains("\"thread_name\""), "lane metadata missing");
    assert!(a.contains("\"ph\": \"C\""), "counter tracks missing");
    assert!(a.contains("st_poison"), "poison instants missing");
    assert!(a.contains("slack @"), "slack counter track missing");
}
