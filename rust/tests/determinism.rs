//! Cycle-determinism regression: the simulator must be a pure function
//! of (compiled program, inputs, config). Two identical runs — and a
//! third with tracing enabled, which changes host-side work but must
//! not change the model — have to agree on every reported number and
//! on final memory. This catches scheduler-order bugs (wake-list
//! iteration order, hash-map iteration leaks) that the functional
//! reference check cannot see.

use dae_spec::coordinator::build_workload;
use dae_spec::sim::{memory_diff, simulate, MachineConfig, SimResult, SimSession};
use dae_spec::transform::{build, Arch};
use dae_spec::workloads::PAPER_KERNELS;

fn assert_same(kernel: &str, arch: Arch, what: &str, a: &SimResult, b: &SimResult) {
    assert_eq!(a.cycles, b.cycles, "{kernel}/{arch:?}: cycles differ ({what})");
    assert_eq!(a.dyn_instrs, b.dyn_instrs, "{kernel}/{arch:?}: dyn_instrs differ ({what})");
    assert_eq!(
        a.stores_committed, b.stores_committed,
        "{kernel}/{arch:?}: stores_committed differ ({what})"
    );
    assert_eq!(
        a.stores_poisoned, b.stores_poisoned,
        "{kernel}/{arch:?}: stores_poisoned differ ({what})"
    );
    assert_eq!(
        memory_diff(&a.memory, &b.memory),
        None,
        "{kernel}/{arch:?}: memory differs ({what})"
    );
    assert_eq!(
        a.commit_log, b.commit_log,
        "{kernel}/{arch:?}: commit log differs ({what})"
    );
}

#[test]
fn repeated_runs_are_cycle_identical() {
    let cfg = MachineConfig::default();
    let traced = MachineConfig { trace: true, ..MachineConfig::default() };
    let mut kernels: Vec<&str> = PAPER_KERNELS.to_vec();
    kernels.push("nested2");
    for kernel in kernels {
        let w = build_workload(kernel, 2026, None).unwrap();
        for arch in [Arch::Sta, Arch::Dae, Arch::Spec] {
            let c = build(&w.module, 0, arch).unwrap();
            let run = |cfg: &MachineConfig| {
                simulate(&c, &w.args, w.memory.clone(), cfg)
                    .unwrap_or_else(|e| panic!("{kernel}/{arch:?}: {e:#}"))
            };
            let a = run(&cfg);
            let b = run(&cfg);
            let t = run(&traced);
            assert_same(kernel, arch, "run 1 vs run 2", &a, &b);
            assert_same(kernel, arch, "untraced vs traced", &a, &t);
            assert!(t.trace.is_some(), "{kernel}/{arch:?}: trace requested but missing");
        }
    }
}

#[test]
fn session_reuse_matches_fresh_simulate_everywhere() {
    // The zero-alloc re-run path: every kernel × arch goes twice through
    // one reused SimSession (in-place reset + memcpy memory restore) and
    // must be bit-identical — cycles, memory, commit log — to a fresh
    // `simulate` call. This is the pin that makes moving the memory
    // clone out of the bench timing loop a measurement fix, not a
    // behaviour change.
    let cfg = MachineConfig::default();
    let mut kernels: Vec<&str> = PAPER_KERNELS.to_vec();
    kernels.push("nested2");
    for kernel in kernels {
        let w = build_workload(kernel, 2026, None).unwrap();
        for arch in [Arch::Sta, Arch::Dae, Arch::Spec] {
            let c = build(&w.module, 0, arch).unwrap();
            let fresh = simulate(&c, &w.args, w.memory.clone(), &cfg)
                .unwrap_or_else(|e| panic!("{kernel}/{arch:?}: fresh simulate: {e:#}"));
            let mut sess = SimSession::new(&c, &cfg, w.memory.clone())
                .unwrap_or_else(|e| panic!("{kernel}/{arch:?}: session alloc: {e:#}"));
            for rerun in 0..2 {
                let stats = sess
                    .run(&w.args)
                    .unwrap_or_else(|e| panic!("{kernel}/{arch:?} run {rerun}: {e:#}"));
                assert_eq!(
                    stats.cycles, fresh.cycles,
                    "{kernel}/{arch:?} run {rerun}: cycles differ from fresh simulate"
                );
                assert_eq!(
                    stats.dyn_instrs, fresh.dyn_instrs,
                    "{kernel}/{arch:?} run {rerun}: dyn_instrs differ"
                );
                assert_eq!(
                    memory_diff(sess.memory(), &fresh.memory),
                    None,
                    "{kernel}/{arch:?} run {rerun}: memory differs"
                );
                assert_eq!(
                    sess.commit_log(),
                    &fresh.commit_log[..],
                    "{kernel}/{arch:?} run {rerun}: commit log differs"
                );
            }
            let result = sess.into_result();
            assert_same(kernel, arch, "reused session vs fresh", &result, &fresh);
            assert_eq!(
                result.per_mem, fresh.per_mem,
                "{kernel}/{arch:?}: per-mem stats differ"
            );
        }
    }
}
