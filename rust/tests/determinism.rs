//! Cycle-determinism regression: the simulator must be a pure function
//! of (compiled program, inputs, config). Two identical runs — and a
//! third with tracing enabled, which changes host-side work but must
//! not change the model — have to agree on every reported number and
//! on final memory. This catches scheduler-order bugs (wake-list
//! iteration order, hash-map iteration leaks) that the functional
//! reference check cannot see.

use dae_spec::coordinator::build_workload;
use dae_spec::sim::{memory_diff, simulate, MachineConfig, SimResult};
use dae_spec::transform::{build, Arch};
use dae_spec::workloads::PAPER_KERNELS;

fn assert_same(kernel: &str, arch: Arch, what: &str, a: &SimResult, b: &SimResult) {
    assert_eq!(a.cycles, b.cycles, "{kernel}/{arch:?}: cycles differ ({what})");
    assert_eq!(a.dyn_instrs, b.dyn_instrs, "{kernel}/{arch:?}: dyn_instrs differ ({what})");
    assert_eq!(
        a.stores_committed, b.stores_committed,
        "{kernel}/{arch:?}: stores_committed differ ({what})"
    );
    assert_eq!(
        a.stores_poisoned, b.stores_poisoned,
        "{kernel}/{arch:?}: stores_poisoned differ ({what})"
    );
    assert_eq!(
        memory_diff(&a.memory, &b.memory),
        None,
        "{kernel}/{arch:?}: memory differs ({what})"
    );
}

#[test]
fn repeated_runs_are_cycle_identical() {
    let cfg = MachineConfig::default();
    let traced = MachineConfig { trace: true, ..MachineConfig::default() };
    let mut kernels: Vec<&str> = PAPER_KERNELS.to_vec();
    kernels.push("nested2");
    for kernel in kernels {
        let w = build_workload(kernel, 2026, None).unwrap();
        for arch in [Arch::Sta, Arch::Dae, Arch::Spec] {
            let c = build(&w.module, 0, arch).unwrap();
            let run = |cfg: &MachineConfig| {
                simulate(&c, &w.args, w.memory.clone(), cfg)
                    .unwrap_or_else(|e| panic!("{kernel}/{arch:?}: {e:#}"))
            };
            let a = run(&cfg);
            let b = run(&cfg);
            let t = run(&traced);
            assert_same(kernel, arch, "run 1 vs run 2", &a, &b);
            assert_same(kernel, arch, "untraced vs traced", &a, &t);
            assert!(t.trace.is_some(), "{kernel}/{arch:?}: trace requested but missing");
        }
    }
}
