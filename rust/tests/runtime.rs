//! Runtime integration: load the AOT artifacts (JAX/Pallas → HLO text),
//! execute via PJRT from Rust, and check the vectorised-speculation
//! engine against the scalar kernels. Requires `make artifacts`.

use dae_spec::runtime::{artifacts_dir, PjrtRuntime, VectorSpecEngine};
use dae_spec::workloads::kernels::{HIST_CAP, THR_T};

fn need_artifacts() -> bool {
    if artifacts_dir().is_none() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return false;
    }
    true
}

#[test]
fn hist_step_artifact_matches_scalar() {
    if !need_artifacts() {
        return;
    }
    let rt = PjrtRuntime::cpu().unwrap();
    let exe = rt.load_artifact("hist_step").unwrap();
    let h: Vec<i64> = (0..256).map(|i| if i % 5 == 0 { HIST_CAP } else { i }).collect();
    let idx: Vec<i64> = (0..256).map(|i| (i * 7) % 256).collect();
    let outs = exe.run_i64(&[&h, &idx]).unwrap();
    assert_eq!(outs.len(), 2);
    let (vals, mask) = (&outs[0], &outs[1]);
    for l in 0..256 {
        let g = h[idx[l] as usize];
        assert_eq!(vals[l], g + 1, "lane {l}");
        assert_eq!(mask[l], (g < HIST_CAP) as i64, "lane {l} mask");
    }
}

#[test]
fn vector_spec_hist_equals_scalar_reference() {
    if !need_artifacts() {
        return;
    }
    let rt = PjrtRuntime::cpu().unwrap();
    let mut eng = VectorSpecEngine::new(&rt, "hist_step", 256).unwrap();

    let mut rng = dae_spec::util::Rng::new(99);
    let n = 2048;
    let d: Vec<i64> = (0..n).map(|_| rng.below(256) as i64).collect();
    let mut h_vec: Vec<i64> = (0..256).map(|b| if b < 8 { HIST_CAP } else { 0 }).collect();
    let mut h_ref = h_vec.clone();

    // scalar reference
    for &v in &d {
        if h_ref[v as usize] < HIST_CAP {
            h_ref[v as usize] += 1;
        }
    }
    eng.run_hist(&mut h_vec, &d, HIST_CAP).unwrap();
    assert_eq!(h_vec, h_ref, "vector-speculated hist must match scalar");
    assert!(eng.stats.batches == (n as u64).div_ceil(256));
    assert!(eng.stats.conflict_lanes > 0, "duplicate bins must trigger replays");
    assert!(eng.stats.masked_lanes > 0, "saturated bins must be masked (poisoned)");
}

#[test]
fn vector_spec_thr_equals_scalar_reference() {
    if !need_artifacts() {
        return;
    }
    let rt = PjrtRuntime::cpu().unwrap();
    let mut eng = VectorSpecEngine::new(&rt, "thr_step", 256).unwrap();
    let mut rng = dae_spec::util::Rng::new(5);
    let n = 1000;
    let mut r: Vec<i64> = (0..n).map(|_| rng.range_i64(0, 200)).collect();
    let mut g: Vec<i64> = (0..n).map(|_| rng.range_i64(0, 200)).collect();
    let mut b: Vec<i64> = (0..n).map(|_| rng.range_i64(0, 200)).collect();
    let (r0, g0, b0) = (r.clone(), g.clone(), b.clone());

    eng.run_thr(&mut r, &mut g, &mut b).unwrap();
    for i in 0..n {
        if r0[i] + g0[i] + b0[i] > THR_T {
            assert_eq!((r[i], g[i], b[i]), (0, 0, 0), "pixel {i} should be zeroed");
        } else {
            assert_eq!((r[i], g[i], b[i]), (r0[i], g0[i], b0[i]), "pixel {i} untouched");
        }
    }
}
