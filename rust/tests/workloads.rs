//! End-to-end integration: every paper kernel × every architecture
//! compiles, simulates, and (except ORACLE) reproduces the reference
//! memory; cycle shapes follow the paper (DAE ≫ STA > SPEC ≈ ORACLE on
//! LoD-bound kernels).

use dae_spec::sim::{machine::simulate, memory_diff, MachineConfig};
use dae_spec::transform::{build, Arch, Compiled};
use dae_spec::workloads::{self, rust_reference, PAPER_KERNELS};
use std::collections::HashMap;

#[test]
fn all_kernels_all_archs_functional() {
    let cfg = MachineConfig::default();
    for name in PAPER_KERNELS {
        let w = workloads::build(name, 2026, None).unwrap();
        let expect = rust_reference(&w);
        for arch in Arch::ALL {
            let c = build(&w.module, 0, arch)
                .unwrap_or_else(|e| panic!("{name}/{arch:?}: build: {e}"));
            let sim = simulate(&c, &w.args, w.memory.clone(), &cfg)
                .unwrap_or_else(|e| panic!("{name}/{arch:?}: sim: {e}"));
            let ok = memory_diff(&sim.memory, &expect).is_none();
            if arch != Arch::Oracle {
                assert!(
                    ok,
                    "{name}/{arch:?}: memory diverges at {:?}",
                    memory_diff(&sim.memory, &expect)
                );
            }
            assert!(sim.cycles > 0, "{name}/{arch:?}: zero cycles");
        }
    }
}

#[test]
fn spec_speculates_on_every_kernel() {
    for name in PAPER_KERNELS {
        let w = workloads::build(name, 7, None).unwrap();
        let c = build(&w.module, 0, Arch::Spec).unwrap();
        let Compiled::Dae { stats, map, .. } = &c else { panic!() };
        let n_spec: usize = map.as_ref().map(|m| m.iter().map(|(_, r)| r.len()).sum()).unwrap_or(0);
        assert!(n_spec > 0, "{name}: nothing speculated");
        assert!(stats.poison_calls > 0, "{name}: no poison calls");
    }
}

#[test]
fn cycle_shapes_follow_paper() {
    // Figure 6's qualitative claims on the sweep-style kernels:
    //   SPEC < STA (speedup), DAE > STA (decoupling lost), SPEC ≈ ORACLE.
    let cfg = MachineConfig::default();
    let mut rows: Vec<(String, HashMap<Arch, u64>)> = Vec::new();
    for name in ["hist", "thr", "mm", "fw", "sort", "spmv", "sssp"] {
        let w = workloads::build(name, 2026, None).unwrap();
        let mut cycles = HashMap::new();
        for arch in Arch::ALL {
            let c = build(&w.module, 0, arch).unwrap();
            let sim = simulate(&c, &w.args, w.memory.clone(), &cfg).unwrap();
            cycles.insert(arch, sim.cycles);
        }
        eprintln!(
            "{name:>6}: STA={} DAE={} SPEC={} ORACLE={}",
            cycles[&Arch::Sta], cycles[&Arch::Dae], cycles[&Arch::Spec], cycles[&Arch::Oracle]
        );
        rows.push((name.to_string(), cycles));
    }
    for (name, c) in &rows {
        assert!(
            c[&Arch::Spec] < c[&Arch::Sta],
            "{name}: SPEC ({}) should beat STA ({})",
            c[&Arch::Spec],
            c[&Arch::Sta]
        );
        assert!(
            c[&Arch::Dae] > c[&Arch::Sta],
            "{name}: DAE ({}) should lose to STA ({}) — LoD sequentialises it",
            c[&Arch::Dae],
            c[&Arch::Sta]
        );
        // SPEC within 25% of ORACLE (paper: within 5% on its testbed)
        let spec = c[&Arch::Spec] as f64;
        let oracle = c[&Arch::Oracle] as f64;
        assert!(
            spec <= oracle * 1.25,
            "{name}: SPEC {} too far from ORACLE {}",
            spec,
            oracle
        );
    }
}

#[test]
fn misspec_rates_track_knobs() {
    let cfg = MachineConfig::default();
    for (name, rate) in [("hist", 0.4), ("thr", 0.6), ("mm", 0.31)] {
        let w = workloads::build(name, 11, Some(rate)).unwrap();
        let c = build(&w.module, 0, Arch::Spec).unwrap();
        let sim = simulate(&c, &w.args, w.memory.clone(), &cfg).unwrap();
        assert!(
            (sim.misspec_rate - rate).abs() < 0.12,
            "{name}: wanted misspec ≈ {rate}, measured {}",
            sim.misspec_rate
        );
    }
}
