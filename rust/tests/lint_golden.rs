//! Golden tests for the semantic linter (`dae_spec::lint`): one
//! positive + one negative hand-written IR snippet per rule family,
//! parsed with `ir::parser`, plus the static/dynamic cross-validation —
//! an IR-level semantic mutation (dropped poison, dropped store push)
//! of a real SPEC build must be flagged *before* any simulation.

use dae_spec::ir::parser::parse_module;
use dae_spec::ir::{BlockId, Module};
use dae_spec::lint::{lint_dae, Rule, Severity};
use dae_spec::transform::decouple::MemOpInfo;
use dae_spec::transform::{
    build, Arch, Compiled, DaeProgram, SpecReq, SpecReqMap,
};

/// Wrap a parsed two-function module (funcs[0] = AGU, funcs[1] = CU)
/// into a `DaeProgram` with the given memory-op table.
fn dae(m: Module, mem_ops: Vec<MemOpInfo>, agu_consumes: Vec<u32>, cu_consumes: Vec<u32>) -> DaeProgram {
    DaeProgram { module: m, agu: 0, cu: 1, mem_ops, agu_consumes, cu_consumes }
}

fn block_named(p: &DaeProgram, fi: usize, name: &str) -> BlockId {
    let f = &p.module.funcs[fi];
    BlockId(f.blocks.iter().position(|b| b.name == name).unwrap() as u32)
}

fn store_op(mem: u32) -> MemOpInfo {
    MemOpInfo { mem, is_store: true, arr: dae_spec::ir::ArrayId(0), home: BlockId(0) }
}

fn load_op(mem: u32) -> MemOpInfo {
    MemOpInfo { mem, is_store: false, arr: dae_spec::ir::ArrayId(0), home: BlockId(0) }
}

// ---------------------------------------------------------------- DEC --

#[test]
fn dec_flags_raw_load_in_access_slice() {
    let m = parse_module(
        r#"
array @A : i64[8]
chan ch0 : st_addr @A
chan ch1 : st_val @A

func @bad__agu(%n: i64) {
entry:
  %c0 = const.i 0
  %v = load @A[%c0]
  send_st_addr ch0:m1, %c0
  ret
}
func @bad__cu(%n: i64) {
entry:
  %c1 = const.i 1
  produce_val ch1:m1, %c1
  ret
}
"#,
    )
    .unwrap();
    let p = dae(m, vec![load_op(0), store_op(1)], vec![], vec![]);
    let rep = lint_dae(None, &p, None);
    assert!(rep.has_error_for(Rule::Decouple), "expected DEC error:\n{}", rep.render(Severity::Info));
    let d = rep.diags.iter().find(|d| d.rule == Rule::Decouple).unwrap();
    assert_eq!(d.func, "bad__agu");
    assert!(d.instr.as_deref().unwrap_or("").contains("load"), "instr not named: {d:?}");
}

#[test]
fn dec_accepts_clean_slices() {
    let m = parse_module(
        r#"
array @A : i64[8]
chan ch0 : st_addr @A
chan ch1 : st_val @A

func @ok__agu(%n: i64) {
entry:
  %c0 = const.i 0
  send_st_addr ch0:m0, %c0
  ret
}
func @ok__cu(%n: i64) {
entry:
  %c1 = const.i 1
  produce_val ch1:m0, %c1
  ret
}
"#,
    )
    .unwrap();
    let p = dae(m, vec![store_op(0)], vec![], vec![]);
    let rep = lint_dae(None, &p, None);
    assert!(!rep.has_errors(), "clean pair must lint clean:\n{}", rep.render(Severity::Info));
}

// --------------------------------------------------------------- CHAN --

#[test]
fn chan_flags_produce_missing_on_one_path() {
    // The AGU sends one store request unconditionally; the CU produces a
    // value only on one arm of a branch the AGU does not have. The two CU
    // paths share every AGU-visible decision, so the counts 1 vs 0 are
    // un-mirrorable.
    let m = parse_module(
        r#"
array @A : i64[8]
chan ch0 : st_addr @A
chan ch1 : st_val @A

func @k__agu(%n: i64) {
entry:
  %c0 = const.i 0
  send_st_addr ch0:m0, %c0
  ret
}
func @k__cu(%n: i64) {
entry:
  %z = const.i 0
  %p = icmp.lt %z, %n
  condbr %p, yes, exit
yes:
  %c1 = const.i 1
  produce_val ch1:m0, %c1
  br exit
exit:
  ret
}
"#,
    )
    .unwrap();
    let p = dae(m, vec![store_op(0)], vec![], vec![]);
    let rep = lint_dae(None, &p, None);
    assert!(rep.has_error_for(Rule::ChanBalance), "expected CHAN error:\n{}", rep.render(Severity::Info));
}

#[test]
fn chan_accepts_branch_mirrored_in_both_slices() {
    // Same guarded store, but the branch exists in both slices (same
    // block names), so paths match key-for-key and balance.
    let m = parse_module(
        r#"
array @A : i64[8]
chan ch0 : st_addr @A
chan ch1 : st_val @A

func @k__agu(%n: i64) {
entry:
  %z = const.i 0
  %p = icmp.lt %z, %n
  condbr %p, yes, exit
yes:
  send_st_addr ch0:m0, %z
  br exit
exit:
  ret
}
func @k__cu(%n: i64) {
entry:
  %z = const.i 0
  %p = icmp.lt %z, %n
  condbr %p, yes, exit
yes:
  %c1 = const.i 1
  produce_val ch1:m0, %c1
  br exit
exit:
  ret
}
"#,
    )
    .unwrap();
    let p = dae(m, vec![store_op(0)], vec![], vec![]);
    let rep = lint_dae(None, &p, None);
    assert!(!rep.has_errors(), "mirrored guard must lint clean:\n{}", rep.render(Severity::Info));
}

// ------------------------------------------------------------- POISON --

fn spec_pair() -> (DaeProgram, SpecReqMap) {
    let m = parse_module(
        r#"
array @A : i64[8]
chan ch0 : ld_addr @A
chan ch1 : ld_val @A
chan ch2 : st_addr @A
chan ch3 : st_val @A

func @s__agu(%n: i64) {
entry:
  %z = const.i 0
  send_ld_addr ch0:m0, %z
  send_st_addr ch2:m1, %z
  ret
}
func @s__cu(%n: i64) {
entry:
  %v = consume_val ch1:m0
  %z = const.i 0
  %p = icmp.lt %z, %n
  condbr %p, home, skip
home:
  br join
skip:
  br join
join:
  produce_val ch3:m1, %v
  ret
}
"#,
    )
    .unwrap();
    let p = dae(m, vec![load_op(0), store_op(1)], vec![], vec![0]);
    let home = block_named(&p, 1, "home");
    let entry = block_named(&p, 1, "entry");
    let map: SpecReqMap = vec![(
        entry,
        vec![SpecReq { mem: 0, is_store: false, arr: dae_spec::ir::ArrayId(0), true_bb: home }],
    )];
    (p, map)
}

#[test]
fn poison_flags_unguarded_speculative_value() {
    // The CU pops the speculated load at `entry` (before the guard) and
    // feeds it to a store value reachable via `skip`, i.e. without ever
    // passing the load's home block — the classic over-read escape.
    let (p, map) = spec_pair();
    let rep = lint_dae(None, &p, Some(&map));
    assert!(rep.has_error_for(Rule::PoisonSound), "expected POISON error:\n{}", rep.render(Severity::Info));
}

#[test]
fn poison_accepts_consume_at_home_block() {
    // Same shape, but the speculative pop happens at the home block
    // itself: the value only exists where the original load executed.
    let m = parse_module(
        r#"
array @A : i64[8]
chan ch0 : ld_addr @A
chan ch1 : ld_val @A
chan ch2 : st_addr @A
chan ch3 : st_val @A

func @s2__agu(%n: i64) {
entry:
  %z = const.i 0
  %p = icmp.lt %z, %n
  condbr %p, home, join
home:
  send_ld_addr ch0:m0, %z
  send_st_addr ch2:m1, %z
  br join
join:
  ret
}
func @s2__cu(%n: i64) {
entry:
  %z = const.i 0
  %p = icmp.lt %z, %n
  condbr %p, home, join
home:
  %v = consume_val ch1:m0
  produce_val ch3:m1, %v
  br join
join:
  ret
}
"#,
    )
    .unwrap();
    let p = dae(m, vec![load_op(0), store_op(1)], vec![], vec![0]);
    let home = block_named(&p, 1, "home");
    let map: SpecReqMap = vec![(
        home,
        vec![SpecReq { mem: 0, is_store: false, arr: dae_spec::ir::ArrayId(0), true_bb: home }],
    )];
    let rep = lint_dae(None, &p, Some(&map));
    assert!(!rep.has_errors(), "guarded consume must lint clean:\n{}", rep.render(Severity::Info));
}

// ----------------------------------------------------------------- SC --

#[test]
fn sc_flags_swapped_store_order() {
    // Two stores to one array: the AGU requests m0 then m1, the CU
    // produces m1 then m0 — Lemma 6.1 pairing would commit swapped
    // values.
    let m = parse_module(
        r#"
array @A : i64[8]
chan ch0 : st_addr @A
chan ch1 : st_val @A

func @o__agu(%n: i64) {
entry:
  %c0 = const.i 0
  send_st_addr ch0:m0, %c0
  send_st_addr ch0:m1, %c0
  ret
}
func @o__cu(%n: i64) {
entry:
  %c1 = const.i 1
  produce_val ch1:m1, %c1
  produce_val ch1:m0, %c1
  ret
}
"#,
    )
    .unwrap();
    let p = dae(m, vec![store_op(0), store_op(1)], vec![], vec![]);
    let rep = lint_dae(None, &p, None);
    assert!(rep.has_error_for(Rule::SeqCst), "expected SC error:\n{}", rep.render(Severity::Info));
}

#[test]
fn sc_accepts_matching_store_order() {
    let m = parse_module(
        r#"
array @A : i64[8]
chan ch0 : st_addr @A
chan ch1 : st_val @A

func @o__agu(%n: i64) {
entry:
  %c0 = const.i 0
  send_st_addr ch0:m0, %c0
  send_st_addr ch0:m1, %c0
  ret
}
func @o__cu(%n: i64) {
entry:
  %c1 = const.i 1
  produce_val ch1:m0, %c1
  produce_val ch1:m1, %c1
  ret
}
"#,
    )
    .unwrap();
    let p = dae(m, vec![store_op(0), store_op(1)], vec![], vec![]);
    let rep = lint_dae(None, &p, None);
    assert!(!rep.has_errors(), "in-order streams must lint clean:\n{}", rep.render(Severity::Info));
}

// ---------------------------------------------------------------- RED --

#[test]
fn red_flags_irreducible_slice() {
    // An a <-> b cycle entered from both sides has no natural-loop
    // decomposition; the path analysis must refuse it loudly instead of
    // reporting wrong balance.
    let m = parse_module(
        r#"
array @A : i64[8]

func @irr__agu(%n: i64) {
entry:
  %z = const.i 0
  %p = icmp.lt %z, %n
  condbr %p, a, b
a:
  br b
b:
  br a
}
func @irr__cu(%n: i64) {
entry:
  ret
}
"#,
    )
    .unwrap();
    let p = dae(m, vec![], vec![], vec![]);
    let rep = lint_dae(None, &p, None);
    assert!(rep.has_error_for(Rule::Reducible), "expected RED error:\n{}", rep.render(Severity::Info));
}

// ----------------------------------------- static/dynamic cross-check --

#[test]
fn spec_mutations_are_flagged_statically() {
    // Every IR-level semantic mutation the fuzz harness can inject into
    // hist's SPEC build must be caught by the linter with no simulation.
    let misses = dae_spec::fault::lint_cross_validate("hist", 2026, false).unwrap();
    assert!(misses.is_empty(), "mutations escaped the linter: {misses:?}");
}

#[test]
fn dropped_poison_yields_structured_diagnostic() {
    use dae_spec::fault::{apply_semantic_mutation, SemanticMutation};
    // Find a paper kernel whose SPEC build carries a poison call, drop
    // it, and require an Error diagnostic naming rule, function and
    // instruction — the acceptance shape for `dae-spec lint`.
    let mut exercised = false;
    for kernel in dae_spec::workloads::PAPER_KERNELS {
        let w = dae_spec::coordinator::build_workload(kernel, 2026, None).unwrap();
        let c = build(&w.module, 0, Arch::Spec).unwrap();
        let Compiled::Dae { program, map, .. } = &c else { panic!("SPEC is decoupled") };
        let mut p = program.clone();
        if apply_semantic_mutation(&mut p, SemanticMutation::DropPoison).is_none() {
            continue; // this kernel's SPEC build needed no poisons
        }
        exercised = true;
        let rep = lint_dae(Some((&w.module, &w.module.funcs[0])), &p, map.as_ref());
        assert!(rep.has_errors(), "{kernel}: dropped poison not flagged");
        let d = rep
            .diags
            .iter()
            .find(|d| d.severity == Severity::Error && d.instr.is_some())
            .unwrap_or_else(|| panic!("{kernel}: no instruction-anchored error diagnostic"));
        assert!(!d.func.is_empty(), "{kernel}: diagnostic names no function");
    }
    assert!(exercised, "no paper kernel produced a poison call in its SPEC build");
}
