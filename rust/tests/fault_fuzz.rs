//! Differential fault-injection fuzzing, end to end:
//!
//! 1. generated (timing-only) plans never change committed memory — the
//!    machine under latency spikes, channel jitter and LSQ squeezes
//!    stays bit-identical to the reference interpreter;
//! 2. a deliberately-injected poison-drop bug (the DU committing the
//!    poison placeholder instead of squashing the store) IS caught as a
//!    divergence, and minimization shrinks the plan to that one fault.

use dae_spec::fault::{
    check_plan, fuzz_kernel, fuzz_sweep, minimize_plan, FaultEvent, FaultPlan, FaultSite,
};
use dae_spec::sim::MachineConfig;
use dae_spec::transform::Arch;

const FUZZ_ARCHS: [Arch; 3] = [Arch::Sta, Arch::Dae, Arch::Spec];

#[test]
fn timing_fault_plans_preserve_memory() {
    let cfg = MachineConfig::default();
    let out = fuzz_kernel("hist", 2026, 5, &FUZZ_ARCHS, &cfg, false).unwrap();
    assert_eq!(out.plans, 5);
    for f in &out.failures {
        eprintln!("{f}");
    }
    assert!(out.ok(), "timing-only plans must never diverge from the reference");
}

#[test]
fn timing_plans_cover_all_kernels_and_nested() {
    // Differential smoke across the whole suite: every paper kernel
    // plus a nested-if workload survives at least one timing-only fault
    // plan per architecture bit-identically to the reference — so the
    // pre-decoded/wake-list engine is cross-checked on every
    // control-flow shape, not just hist.
    let cfg = MachineConfig::default();
    let mut kernels: Vec<&str> = dae_spec::workloads::PAPER_KERNELS.to_vec();
    kernels.push("nested3");
    for kernel in kernels {
        let out = fuzz_kernel(kernel, 2026, 1, &FUZZ_ARCHS, &cfg, false)
            .unwrap_or_else(|e| panic!("{kernel}: fuzz harness error: {e:#}"));
        for f in &out.failures {
            eprintln!("{f}");
        }
        assert!(out.ok(), "{kernel}: timing-only plan diverged from the reference");
    }
}

#[test]
fn fuzz_is_deterministic_across_runs() {
    // same base seed → identical plans → identical verdicts
    let p1: Vec<FaultPlan> = (0..4).map(|i| FaultPlan::generate(99, i)).collect();
    let p2: Vec<FaultPlan> = (0..4).map(|i| FaultPlan::generate(99, i)).collect();
    assert_eq!(p1, p2);
}

#[test]
fn parallel_fuzz_sweep_matches_serial() {
    // `dae-spec fuzz --jobs N` fans the kernel × plan × arch grid over
    // the worker pool; the outcomes must be identical to the serial
    // sweep (jobs=1) in content AND order — plan generation, cell
    // enumeration and result merging are all job-count independent.
    let cfg = MachineConfig::default();
    let kernels = vec!["hist".to_string(), "thr".to_string()];
    let serial = fuzz_sweep(&kernels, 2026, 3, &FUZZ_ARCHS, &cfg, 1, false).unwrap();
    let parallel = fuzz_sweep(&kernels, 2026, 3, &FUZZ_ARCHS, &cfg, 4, false).unwrap();
    assert_eq!(serial.len(), parallel.len());
    for (s, p) in serial.iter().zip(&parallel) {
        assert_eq!(s.kernel, p.kernel, "outcome order must match the kernel list");
        assert_eq!(s.plans, p.plans);
        assert_eq!(s.archs, p.archs);
        assert_eq!(
            s.failures.len(),
            p.failures.len(),
            "{}: serial and parallel sweeps disagree",
            s.kernel
        );
        for (sf, pf) in s.failures.iter().zip(&p.failures) {
            assert_eq!(sf.plan_index, pf.plan_index);
            assert_eq!(sf.arch, pf.arch);
            assert_eq!(sf.desc, pf.desc);
        }
        // timing-only generated plans: both paths must also be clean
        assert!(s.ok(), "{}: timing-only plan diverged (serial)", s.kernel);
        assert!(p.ok(), "{}: timing-only plan diverged (parallel)", p.kernel);
    }

    // and the per-kernel wrapper is the jobs=1 sweep
    let single = fuzz_kernel("hist", 2026, 3, &FUZZ_ARCHS, &cfg, false).unwrap();
    assert_eq!(single.kernel, serial[0].kernel);
    assert_eq!(single.failures.len(), serial[0].failures.len());
}

fn poison_drop_plan() -> FaultPlan {
    FaultPlan {
        seed: 0xBAD5EED,
        index: 0,
        events: vec![FaultEvent {
            site: FaultSite::DropPoison,
            from: 0,
            until: u64::MAX,
            magnitude: 1,
        }],
        // storm the speculated store: half the hist updates hit a
        // saturated bin and must be squashed via poison
        misspec: Some(0.5),
    }
}

#[test]
fn injected_poison_drop_bug_is_caught() {
    let cfg = MachineConfig::default();
    let plan = poison_drop_plan();
    // SPEC is the only arch that emits poisons; the bug must surface
    // as a memory divergence against the reference interpreter.
    let verdict = check_plan("hist", &plan, Arch::Spec, &cfg).unwrap();
    let desc = verdict.expect("dropping poison must diverge from the reference");
    assert!(
        desc.contains("diverges"),
        "divergence description names the mismatch: {desc}"
    );

    // STA/DAE never poison, so the same plan is harmless there
    for arch in [Arch::Sta, Arch::Dae] {
        assert_eq!(
            check_plan("hist", &plan, arch, &cfg).unwrap(),
            None,
            "{arch:?} has no speculation to break"
        );
    }
}

#[test]
fn failing_plan_minimizes_to_the_poison_drop() {
    let cfg = MachineConfig::default();
    // pad the plan with irrelevant timing noise that minimization
    // should strip away
    let mut plan = poison_drop_plan();
    plan.events.insert(
        0,
        FaultEvent { site: FaultSite::MemReadDelay, from: 0, until: 5_000, magnitude: 9 },
    );
    plan.events.push(FaultEvent {
        site: FaultSite::ChanPushDelay,
        from: 100,
        until: 9_000,
        magnitude: 4,
    });

    assert!(check_plan("hist", &plan, Arch::Spec, &cfg).unwrap().is_some());
    let min = minimize_plan("hist", &plan, Arch::Spec, &cfg).unwrap();
    assert_eq!(min.events.len(), 1, "minimized plan keeps one event: {min}");
    assert_eq!(min.events[0].site, FaultSite::DropPoison);
    assert_eq!(min.misspec, None, "default misspec rate already reproduces");
    // and the minimized plan still fails
    assert!(check_plan("hist", &min, Arch::Spec, &cfg).unwrap().is_some());
}
