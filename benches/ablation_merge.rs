//! §5.3 ablation: poison-block merging on/off across the nested-if
//! template — how many blocks the merge pass recovers.

use dae_spec::analysis::{DomTree, LodAnalysis, LoopInfo, Reachability};
use dae_spec::transform::{decouple, hoist_speculative_requests, merge_poison, place_poisons};
use dae_spec::workloads::nested::nested;

fn main() {
    println!("== §5.3 ablation: poison-block merging (nested template) ==");
    println!("{:<8}{:>14}{:>12}{:>12}", "levels", "blocks (raw)", "merged", "final");
    for levels in 1..=8 {
        let w = nested(levels, 2026);
        let f = &w.module.funcs[0];
        let lod = LodAnalysis::new(&w.module, f);
        let dom = DomTree::new(f);
        let loops = LoopInfo::new(f, &dom);
        let reach = Reachability::new(f, &dom);
        let mut p = decouple(&w.module, f, false);
        let hr = hoist_speculative_requests(&mut p, &lod, &dom, &loops, &reach);
        let stats = place_poisons(&mut p, &hr.map).unwrap();
        let cu = p.cu;
        let merged = merge_poison::run(&mut p.module.funcs[cu]);
        println!(
            "{:<8}{:>14}{:>12}{:>12}",
            levels,
            stats.poison_blocks,
            merged,
            stats.poison_blocks - merged
        );
    }
    println!("(paper mm: two poison blocks merged into one)");
}
