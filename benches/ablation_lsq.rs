//! §8.2.1 ablation: store-queue size sensitivity on the deep-pipeline,
//! high-mis-speculation graph kernels (paper: mis-speculated stores can
//! fill the LSQ and stall later loads; larger store queues recover).

use dae_spec::coordinator::report;

fn main() {
    report::lsq_sweep("bfs", 2026, &[2, 4, 8, 16, 32, 64]).unwrap();
    report::lsq_sweep("bc", 2026, &[2, 4, 8, 16, 32, 64]).unwrap();
    report::lsq_sweep("hist", 2026, &[2, 4, 8, 16, 32, 64]).unwrap();
}
