//! Regenerates paper Figure 2: pipeline timelines of decoupled vs
//! non-decoupled address generation.

use dae_spec::coordinator::report;

fn main() {
    report::fig2(2026).unwrap();
}
