//! L3 microbenchmarks for the §Perf pass: compiler pipeline latency and
//! simulator throughput (dynamic instructions / second).

use dae_spec::sim::machine::simulate;
use dae_spec::sim::MachineConfig;
use dae_spec::transform::{build, Arch};
use dae_spec::util::Bench;

fn main() {
    let b = Bench::new(2, 10);
    // compiler pipeline: all 9 kernels × SPEC
    b.run("compile SPEC × 9 kernels", || {
        for name in dae_spec::workloads::PAPER_KERNELS {
            let w = dae_spec::workloads::build(name, 1, None).unwrap();
            std::hint::black_box(build(&w.module, 0, Arch::Spec).unwrap());
        }
    });
    // simulator throughput on the largest kernel
    let w = dae_spec::workloads::build("sssp", 1, None).unwrap();
    let spec = build(&w.module, 0, Arch::Spec).unwrap();
    let cfg = MachineConfig::default();
    let stats = b.run("simulate sssp SPEC (full run)", || {
        simulate(&spec, &w.args, w.memory.clone(), &cfg).unwrap()
    });
    let sim = simulate(&spec, &w.args, w.memory.clone(), &cfg).unwrap();
    let dyn_i = sim.dyn_instrs as f64;
    println!(
        "simulator throughput: {:.1} M dyn-instrs/s  ({} instrs / run)",
        dyn_i / stats.min_ns * 1000.0,
        sim.dyn_instrs
    );
}
