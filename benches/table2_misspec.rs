//! Regenerates paper Table 2: SPEC cycles as the mis-speculation rate is
//! swept 0..100% on hist/thr/mm — the "no mis-speculation cost" claim.

use dae_spec::coordinator::report;

fn main() {
    report::table2(2026).unwrap();
}
