//! Regenerates paper Table 1 (absolute cycles + area, poison counts,
//! mis-speculation rates for STA/DAE/SPEC/ORACLE × 9 kernels) and times
//! the full suite run.

use dae_spec::coordinator::report;
use dae_spec::util::Bench;

fn main() {
    let t0 = std::time::Instant::now();
    report::table1(2026).unwrap();
    println!("\n[table1 wall time: {:.2?}]", t0.elapsed());

    // compile+simulate throughput for one representative kernel
    let b = Bench::new(1, 5);
    b.run("compile+sim hist × 4 archs", || {
        let cfg = dae_spec::sim::MachineConfig::default();
        dae_spec::coordinator::run_kernel("hist", 1, None, &dae_spec::transform::Arch::ALL, &cfg, false)
            .unwrap()
    });
}
