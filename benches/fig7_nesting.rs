//! Regenerates paper Figure 7: SPEC-over-ORACLE area and performance
//! overhead as nested control flow grows poison blocks (1..8 levels;
//! poison calls grow as n(n+1)/2).

use dae_spec::coordinator::report;

fn main() {
    report::fig7(2026).unwrap();
}
