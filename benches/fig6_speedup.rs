//! Regenerates paper Figure 6: DAE/SPEC/ORACLE speedups over STA with
//! harmonic means (paper headline: SPEC avg 1.9×, up to 3×).

use dae_spec::coordinator::report;

fn main() {
    report::fig6(2026).unwrap();
}
