//! §10 (future work, implemented here): vectorised speculation
//! throughput via the AOT-compiled XLA step function vs the scalar loop.
//! Requires `make artifacts`.

use dae_spec::runtime::{PjrtRuntime, VectorSpecEngine};
use dae_spec::util::{Bench, Rng};
use dae_spec::workloads::kernels::HIST_CAP;

fn main() {
    let Some(_) = dae_spec::runtime::artifacts_dir() else {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    };
    let rt = PjrtRuntime::cpu().unwrap();
    let mut rng = Rng::new(7);
    let n = 64 * 1024;
    let d: Vec<i64> = (0..n).map(|_| rng.below(256) as i64).collect();
    let h0: Vec<i64> = (0..256).map(|b| if b < 32 { HIST_CAP } else { 0 }).collect();

    let b = Bench::new(2, 8);
    b.run("scalar hist (guarded update loop)", || {
        let mut h = h0.clone();
        for &v in &d {
            if h[v as usize] < HIST_CAP {
                h[v as usize] += 1;
            }
        }
        h
    });
    let mut eng = VectorSpecEngine::new(&rt, "hist_step", 256).unwrap();
    b.run("vector-speculated hist (XLA batch=256)", || {
        let mut h = h0.clone();
        eng.run_hist(&mut h, &d, HIST_CAP).unwrap();
        h
    });
    println!(
        "lanes={} masked(poisoned)={} conflicts(replayed)={} batches={}",
        eng.stats.lanes, eng.stats.masked_lanes, eng.stats.conflict_lanes, eng.stats.batches
    );
}
