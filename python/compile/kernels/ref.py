"""Pure-jnp oracle for the vectorised-speculation step kernels (L1).

These are the golden semantics the Pallas kernels in `spec_mask.py` are
checked against by pytest/hypothesis. Shapes follow the paper's §10
future-work sketch: a vector of speculative requests produces per-lane
store values plus a *store mask* (the vector poison bit).
"""

import jax.numpy as jnp

HIST_CAP = 1 << 20
THR_T = 300
SPMV_CAP = 1 << 30


def hist_step_ref(h, idx):
    """Guarded histogram update: values = H[idx] + 1, mask = H[idx] < CAP.

    `idx` must be pre-clamped (the Rust DU clamps speculative addresses).
    """
    gathered = h[idx]
    vals = gathered + 1
    mask = (gathered < HIST_CAP).astype(jnp.int64)
    return vals, mask


def thr_step_ref(r, g, b):
    """Store mask for the RGB threshold kernel: sum > T."""
    mask = ((r + g + b) > THR_T).astype(jnp.int64)
    return (mask,)


def spmv_step_ref(y, cols, prods):
    """Saturating scatter-accumulate step."""
    gathered = y[cols]
    vals = gathered + prods
    mask = (gathered < SPMV_CAP).astype(jnp.int64)
    return vals, mask
