"""L1 — Pallas kernels for the vectorised-speculation step functions.

The per-lane guarded-update compute (store values + store mask) runs as a
Pallas kernel; gathers/scatters stay outside (the DU owns memory, exactly
as in the paper's architecture — TPU Pallas has no efficient dynamic
scatter, see DESIGN.md §Hardware-Adaptation).

TPU mapping notes (§Hardware-Adaptation):
- lane-blocked 1-D grid via `BlockSpec((LANE_BLOCK,), ...)` — each block
  fits VMEM trivially (3 × LANE_BLOCK × 8 B);
- predication is *data* (the mask), not control: `jnp.where`/comparisons
  vectorise on the VPU, mirroring the paper's poison-bit semantics where
  mis-speculation never branches;
- `interpret=True` everywhere: this image's PJRT is CPU-only; real-TPU
  lowering would emit a Mosaic custom-call (compile-only target).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

LANE_BLOCK = 128


def _grid(n):
    assert n % LANE_BLOCK == 0, f"batch {n} must be a multiple of {LANE_BLOCK}"
    return (n // LANE_BLOCK,)


def _spec(n):
    del n
    return pl.BlockSpec((LANE_BLOCK,), lambda i: (i,))


def _guarded_inc_kernel(g_ref, vals_ref, mask_ref):
    """vals = g + 1; mask = g < CAP (the hist update)."""
    g = g_ref[...]
    vals_ref[...] = g + 1
    mask_ref[...] = (g < ref.HIST_CAP).astype(jnp.int64)


def guarded_inc(gathered):
    """Pallas version of the hist update over pre-gathered guard values."""
    n = gathered.shape[0]
    return pl.pallas_call(
        _guarded_inc_kernel,
        grid=_grid(n),
        in_specs=[_spec(n)],
        out_specs=(_spec(n), _spec(n)),
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.int64),
            jax.ShapeDtypeStruct((n,), jnp.int64),
        ),
        interpret=True,
    )(gathered)


def _thr_mask_kernel(r_ref, g_ref, b_ref, mask_ref):
    """mask = (r + g + b) > T."""
    s = r_ref[...] + g_ref[...] + b_ref[...]
    mask_ref[...] = (s > ref.THR_T).astype(jnp.int64)


def thr_mask(r, g, b):
    n = r.shape[0]
    return pl.pallas_call(
        _thr_mask_kernel,
        grid=_grid(n),
        in_specs=[_spec(n), _spec(n), _spec(n)],
        out_specs=(_spec(n),),
        out_shape=(jax.ShapeDtypeStruct((n,), jnp.int64),),
        interpret=True,
    )(r, g, b)


def _saturating_add_kernel(g_ref, p_ref, vals_ref, mask_ref):
    """vals = g + p; mask = g < CAP (the spmv accumulate)."""
    g = g_ref[...]
    vals_ref[...] = g + p_ref[...]
    mask_ref[...] = (g < ref.SPMV_CAP).astype(jnp.int64)


def saturating_add(gathered, prods):
    n = gathered.shape[0]
    return pl.pallas_call(
        _saturating_add_kernel,
        grid=_grid(n),
        in_specs=[_spec(n), _spec(n)],
        out_specs=(_spec(n), _spec(n)),
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.int64),
            jax.ShapeDtypeStruct((n,), jnp.int64),
        ),
        interpret=True,
    )(gathered, prods)
