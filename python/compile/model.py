"""L2 — JAX step functions for vectorised speculation (paper §10).

Each variant composes: gather (speculative vector request) → L1 Pallas
kernel (per-lane values + store mask) → outputs. The Rust coordinator
(`runtime::vector_spec`) applies the masked scatter; Python never runs on
the request path.

Shapes are fixed at AOT time (one compiled executable per variant — one
HLO artifact each, loaded once by the Rust runtime).
"""

import jax.numpy as jnp

from .kernels import spec_mask

BATCH = 256
HIST_BINS = 256
SPMV_N = 32  # padded up from the scalar kernel's 20 (fixed-shape AOT)


def hist_step(h, idx):
    """(H[bins], idx[batch]) -> (new_vals[batch], mask[batch])."""
    idx = jnp.clip(idx, 0, h.shape[0] - 1)
    gathered = h[idx]
    vals, mask = spec_mask.guarded_inc(gathered)
    return vals, mask


def thr_step(r, g, b):
    """(r, g, b)[batch] -> (mask[batch],) — store mask for the zeroing."""
    return spec_mask.thr_mask(r, g, b)


def spmv_step(y, cols, prods):
    """(y[n], cols[batch], prods[batch]) -> (new_vals, mask)."""
    cols = jnp.clip(cols, 0, y.shape[0] - 1)
    gathered = y[cols]
    vals, mask = spec_mask.saturating_add(gathered, prods)
    return vals, mask


def variants():
    """AOT variants: name -> (fn, example shapes)."""
    i64 = jnp.int64
    import jax

    def spec(shape):
        return jax.ShapeDtypeStruct(shape, i64)

    return {
        "hist_step": (hist_step, (spec((HIST_BINS,)), spec((BATCH,)))),
        "thr_step": (thr_step, (spec((BATCH,)), spec((BATCH,)), spec((BATCH,)))),
        "spmv_step": (spmv_step, (spec((SPMV_N,)), spec((BATCH,)), spec((BATCH,)))),
    }
