"""AOT lowering: JAX (L2, calling L1 Pallas) → HLO **text** artifacts.

HLO text — not `lowered.compile()` or proto `.serialize()` — is the
interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit
instruction ids which the Rust side's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage: python -m compile.aot --out ../artifacts
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    for name, (fn, example) in model.variants().items():
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
