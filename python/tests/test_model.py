"""L2 correctness + AOT smoke: the full step functions (gather + Pallas
kernel) against end-to-end oracles, and HLO-text emission of every
variant."""

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from compile import aot, model  # noqa: E402
from compile.kernels import ref  # noqa: E402


def test_hist_step_end_to_end():
    h = jnp.zeros(model.HIST_BINS, dtype=jnp.int64).at[3].set(ref.HIST_CAP)
    idx = jnp.asarray([0, 3, 3, 5] * (model.BATCH // 4), dtype=jnp.int64)
    vals, mask = model.hist_step(h, idx)
    exp_vals, exp_mask = ref.hist_step_ref(h, jnp.clip(idx, 0, h.shape[0] - 1))
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(exp_vals))
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(exp_mask))
    # bin 3 is saturated → mask 0 (poisoned lanes)
    assert int(mask[1]) == 0 and int(mask[2]) == 0
    assert int(mask[0]) == 1


def test_hist_step_clamps_speculative_addresses():
    h = jnp.zeros(model.HIST_BINS, dtype=jnp.int64)
    idx = jnp.full((model.BATCH,), -7, dtype=jnp.int64)  # wild speculative address
    vals, mask = model.hist_step(h, idx)
    assert np.all(np.asarray(vals) == 1)  # clamped to bin 0


def test_spmv_step_end_to_end():
    y = jnp.arange(model.SPMV_N, dtype=jnp.int64)
    cols = jnp.asarray(list(range(model.BATCH)), dtype=jnp.int64) % model.SPMV_N
    prods = jnp.ones((model.BATCH,), dtype=jnp.int64) * 4
    vals, mask = model.spmv_step(y, cols, prods)
    exp_vals, exp_mask = ref.spmv_step_ref(y, cols, prods)
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(exp_vals))
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(exp_mask))


def test_all_variants_lower_to_hlo_text():
    for name, (fn, example) in model.variants().items():
        lowered = jax.jit(fn).lower(*example)
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text, f"{name}: no HLO emitted"
        # outputs are a tuple (return_tuple=True) — the Rust loader
        # unwraps with to_tuple()
        assert "tuple" in text.lower(), f"{name}: expected tuple root"
