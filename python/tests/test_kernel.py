"""L1 correctness: Pallas kernels vs the pure-jnp oracle (`ref.py`).

Hypothesis sweeps shapes and values; exact integer equality is required
(the kernels are integer ALU ops — no tolerance games).
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels import ref, spec_mask  # noqa: E402

LANE = spec_mask.LANE_BLOCK


def lanes_strategy(max_blocks=4):
    return st.integers(min_value=1, max_value=max_blocks).map(lambda k: k * LANE)


@settings(max_examples=20, deadline=None)
@given(
    n=lanes_strategy(),
    data=st.data(),
)
def test_guarded_inc_matches_ref(n, data):
    vals = data.draw(
        st.lists(
            st.integers(min_value=0, max_value=ref.HIST_CAP + 5),
            min_size=n,
            max_size=n,
        )
    )
    h = jnp.arange(256, dtype=jnp.int64) * 3  # arbitrary bin contents
    gathered = jnp.asarray(vals, dtype=jnp.int64)
    got_vals, got_mask = spec_mask.guarded_inc(gathered)
    # oracle on the same gathered values
    exp_vals = gathered + 1
    exp_mask = (gathered < ref.HIST_CAP).astype(jnp.int64)
    np.testing.assert_array_equal(np.asarray(got_vals), np.asarray(exp_vals))
    np.testing.assert_array_equal(np.asarray(got_mask), np.asarray(exp_mask))
    del h


@settings(max_examples=20, deadline=None)
@given(n=lanes_strategy(), data=st.data())
def test_thr_mask_matches_ref(n, data):
    mk = lambda: jnp.asarray(
        data.draw(
            st.lists(st.integers(min_value=0, max_value=400), min_size=n, max_size=n)
        ),
        dtype=jnp.int64,
    )
    r, g, b = mk(), mk(), mk()
    (got,) = spec_mask.thr_mask(r, g, b)
    (exp,) = ref.thr_step_ref(r, g, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


@settings(max_examples=20, deadline=None)
@given(n=lanes_strategy(), data=st.data())
def test_saturating_add_matches_ref(n, data):
    g = jnp.asarray(
        data.draw(
            st.lists(
                st.integers(min_value=0, max_value=ref.SPMV_CAP + 9),
                min_size=n,
                max_size=n,
            )
        ),
        dtype=jnp.int64,
    )
    p = jnp.asarray(
        data.draw(
            st.lists(st.integers(min_value=-50, max_value=50), min_size=n, max_size=n)
        ),
        dtype=jnp.int64,
    )
    got_vals, got_mask = spec_mask.saturating_add(g, p)
    exp_vals = g + p
    exp_mask = (g < ref.SPMV_CAP).astype(jnp.int64)
    np.testing.assert_array_equal(np.asarray(got_vals), np.asarray(exp_vals))
    np.testing.assert_array_equal(np.asarray(got_mask), np.asarray(exp_mask))


def test_mask_boundary_exact():
    """CAP-1 keeps, CAP poisons — the poison bit must be exact."""
    g = jnp.asarray([ref.HIST_CAP - 1, ref.HIST_CAP, 0, ref.HIST_CAP + 1], dtype=jnp.int64)
    g = jnp.tile(g, LANE // 4)
    _, mask = spec_mask.guarded_inc(g)
    expect = jnp.tile(jnp.asarray([1, 0, 1, 0], dtype=jnp.int64), LANE // 4)
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(expect))
