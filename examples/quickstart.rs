//! Quickstart: the paper's running example (Fig. 1) through the whole
//! stack — parse the kernel, compile all four architectures, simulate,
//! and print the speedups and the transformed slices.
//!
//!     cargo run --release --example quickstart

use dae_spec::ir::parser::parse_module;
use dae_spec::ir::types::Val;
use dae_spec::sim::machine::simulate;
use dae_spec::sim::{zero_memory, MachineConfig};
use dae_spec::transform::{build, Arch, Compiled};

const FIG1: &str = r#"
array @A : i64[256]
array @idx : i64[256]

func @fig1(%n: i64) {
entry:
  %c0 = const.i 0
  br header
header:
  %i = phi i64 [entry: %c0], [latch: %inext]
  %cc = icmp.lt %i, %n
  condbr %cc, body, exit
body:
  %a = load @A[%i]
  %zero = const.i 0
  %p = icmp.gt %a, %zero
  condbr %p, then, latch
then:
  %w = load @idx[%i]
  %aw = load @A[%w]
  %c1 = const.i 1
  %fv = add.i %aw, %c1
  store @A[%w], %fv
  br latch
latch:
  %c1b = const.i 1
  %inext = add.i %i, %c1b
  br header
exit:
  ret
}
"#;

fn main() -> anyhow::Result<()> {
    let m = parse_module(FIG1)?;
    // seeded data: ~half the guards fire
    let mut mem = zero_memory(&m);
    let mut rng = dae_spec::util::Rng::new(42);
    for i in 0..256 {
        mem[0][i] = Val::I(rng.range_i64(-10, 10));
        mem[1][i] = Val::I(rng.below(256) as i64);
    }
    let cfg = MachineConfig::default();

    println!("== paper Fig. 1 kernel: if (A[i] > 0) A[idx[i]] = f(A[idx[i]]) ==\n");
    let mut sta_cycles = 0;
    for arch in Arch::ALL {
        let c = build(&m, 0, arch)?;
        let sim = simulate(&c, &[Val::I(256)], mem.clone(), &cfg)?;
        if arch == Arch::Sta {
            sta_cycles = sim.cycles;
        }
        println!(
            "{:>7}: {:>6} cycles  speedup vs STA: {:>5.2}x  misspec: {:>4.1}%",
            arch.name(),
            sim.cycles,
            sta_cycles as f64 / sim.cycles as f64,
            sim.misspec_rate * 100.0
        );
        if arch == Arch::Spec {
            if let Compiled::Dae { program, stats, .. } = &c {
                println!(
                    "         poison blocks: {}, poison calls: {}",
                    stats.poison_blocks, stats.poison_calls
                );
                println!("\n--- SPEC AGU slice (store request speculated out of the branch) ---");
                print!(
                    "{}",
                    dae_spec::ir::printer::print_function(&program.module, program.agu_fn())
                );
                println!("--- SPEC CU slice (poison call on the skip path) ---");
                print!(
                    "{}",
                    dae_spec::ir::printer::print_function(&program.module, program.cu_fn())
                );
                println!();
            }
        }
    }
    Ok(())
}
