//! Mis-speculation cost sweep (paper Table 2 as an API example): drive
//! the data-generator knob from 0% to 100% and show SPEC cycles are flat
//! — poisoned stores cost nothing (§8.2.1).
//!
//!     cargo run --release --example misspec_sweep

use dae_spec::coordinator::runner::run_kernel;
use dae_spec::sim::MachineConfig;
use dae_spec::transform::Arch;

fn main() -> anyhow::Result<()> {
    let cfg = MachineConfig::default();
    for kernel in ["hist", "thr", "mm"] {
        println!("== {kernel} ==");
        println!("{:>10}{:>14}{:>14}", "rate", "SPEC cycles", "measured");
        for pct in [0, 20, 40, 60, 80, 100] {
            let rate = pct as f64 / 100.0;
            let row = run_kernel(kernel, 7, Some(rate), &[Arch::Spec], &cfg, true)?;
            println!(
                "{:>9}%{:>14}{:>13.0}%",
                pct,
                row.cycles[&Arch::Spec],
                row.misspec_rate * 100.0
            );
        }
        println!();
    }
    println!("(flat columns = no mis-speculation penalty, the paper's Table 2 claim)");
    Ok(())
}
