//! End-to-end driver (the repo's headline validation): run the paper's
//! graph-analytics kernels (bfs, bc, sssp) on the synthetic
//! email-Eu-core-scale graph (1005 nodes / 25 571 edges) through the
//! full system — LoD analysis, decoupling, Algorithm 1-3 speculation,
//! cycle-level simulation on all four architectures — with functional
//! cross-checks, and report the paper's headline metric (SPEC speedup
//! over STA; paper: avg 1.9×, up to 3×).
//!
//!     cargo run --release --example graph_analytics

use dae_spec::coordinator::runner::run_kernel;
use dae_spec::sim::MachineConfig;
use dae_spec::transform::Arch;

fn main() -> anyhow::Result<()> {
    let cfg = MachineConfig::default();
    println!("graph: synthetic email-Eu-core stand-in (1005 nodes, 25571 edges)\n");
    println!(
        "{:<6}{:>11}{:>11}{:>11}{:>11}{:>9}{:>10}{:>9}",
        "kernel", "STA", "DAE", "SPEC", "ORACLE", "speedup", "misspec", "checked"
    );
    let mut speedups = Vec::new();
    for kernel in ["bfs", "bc", "sssp"] {
        let t0 = std::time::Instant::now();
        // check=true: STA/DAE/SPEC final memory must equal the reference
        // interpreter (run_kernel fails otherwise)
        let row = run_kernel(kernel, 2026, None, &Arch::ALL, &cfg, true)?;
        let s = row.cycles[&Arch::Sta] as f64 / row.cycles[&Arch::Spec] as f64;
        speedups.push(s);
        println!(
            "{:<6}{:>11}{:>11}{:>11}{:>11}{:>8.2}x{:>9.0}%{:>9}",
            kernel,
            row.cycles[&Arch::Sta],
            row.cycles[&Arch::Dae],
            row.cycles[&Arch::Spec],
            row.cycles[&Arch::Oracle],
            s,
            row.misspec_rate * 100.0,
            format!("ok {:.1?}", t0.elapsed()),
        );
    }
    let hmean = speedups.len() as f64 / speedups.iter().map(|s| 1.0 / s).sum::<f64>();
    println!(
        "\nheadline: SPEC speedup over STA on graph kernels — harmonic mean {hmean:.2}x \
         (paper overall: 1.9x avg, up to 3x)"
    );
    Ok(())
}
