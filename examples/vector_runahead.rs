//! Vectorised speculation (paper §10 future work) on the real runtime
//! path: batched speculative requests run through the AOT-compiled
//! JAX/Pallas step functions via PJRT, with store masks as the vector
//! poison bit and serial replay of intra-batch conflicts.
//!
//!     make artifacts && cargo run --release --example vector_runahead

use dae_spec::runtime::{PjrtRuntime, VectorSpecEngine};
use dae_spec::util::Rng;
use dae_spec::workloads::kernels::{HIST_CAP, THR_T};

fn main() -> anyhow::Result<()> {
    if dae_spec::runtime::artifacts_dir().is_none() {
        anyhow::bail!("artifacts/ missing — run `make artifacts` first");
    }
    let rt = PjrtRuntime::cpu()?;
    println!("PJRT platform: {}\n", rt.platform());

    // --- hist: guarded saturating histogram over 32k elements ---
    let mut rng = Rng::new(11);
    let n = 32 * 1024;
    let d: Vec<i64> = (0..n).map(|_| rng.below(256) as i64).collect();
    let mut h: Vec<i64> = (0..256).map(|b| if b % 8 == 0 { HIST_CAP } else { 0 }).collect();
    let mut h_ref = h.clone();
    for &v in &d {
        if h_ref[v as usize] < HIST_CAP {
            h_ref[v as usize] += 1;
        }
    }
    let mut eng = VectorSpecEngine::new(&rt, "hist_step", 256)?;
    let t0 = std::time::Instant::now();
    eng.run_hist(&mut h, &d, HIST_CAP)?;
    let dt = t0.elapsed();
    assert_eq!(h, h_ref, "vectorised hist must match scalar semantics");
    println!(
        "hist:  {n} elements in {dt:.2?} — {} batches, {} poisoned lanes ({:.1}%), {} conflict replays — matches scalar ✓",
        eng.stats.batches,
        eng.stats.masked_lanes,
        eng.stats.masked_lanes as f64 / eng.stats.lanes as f64 * 100.0,
        eng.stats.conflict_lanes
    );

    // --- thr: RGB thresholding over 16k pixels ---
    let n = 16 * 1024;
    let mut r: Vec<i64> = (0..n).map(|_| rng.range_i64(0, 200)).collect();
    let mut g: Vec<i64> = (0..n).map(|_| rng.range_i64(0, 200)).collect();
    let mut b: Vec<i64> = (0..n).map(|_| rng.range_i64(0, 200)).collect();
    let expect_zeroed =
        (0..n).filter(|&i| r[i] + g[i] + b[i] > THR_T).count();
    let mut eng = VectorSpecEngine::new(&rt, "thr_step", 256)?;
    let t0 = std::time::Instant::now();
    eng.run_thr(&mut r, &mut g, &mut b)?;
    println!(
        "thr:   {n} pixels in {:.2?} — {} zeroed, {} kept (poisoned) — store-mask semantics ✓",
        t0.elapsed(),
        expect_zeroed,
        eng.stats.masked_lanes
    );
    Ok(())
}
